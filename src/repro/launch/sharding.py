"""Name-pattern parameter/activation partitioner (2D TP + FSDP).

Logical rules (mesh axes: optional "pod" + "data" + "model"):

* weights: d_model-like dims shard over "data" (FSDP — all-gathered per
  layer under the scan), head/ffn/vocab dims over "model" (tensor
  parallelism).  "pod" never shards weights (pure DP: weights replicated
  across pods, gradient all-reduce crosses DCN once per step).
* MoE experts shard over "data" (expert parallelism) with expert-ffn over
  "model".
* activations/caches: batch over ("pod","data") when divisible; full
  KV-cache sequence dim over "model" (decode is weight- and cache-bound;
  sequence-sharded attention is flash-decode across chips).
* anything small (norms, biases, scalars, LoRA A) replicates.

Dims that do not divide their assigned axis fall back to replication — the
partitioner is total: every leaf gets a valid spec.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _fit(dim: int, axis: Optional[str], mesh: Mesh):
    """axis if it divides dim else None."""
    if axis is None or axis not in mesh.axis_names:
        return None
    return axis if dim % mesh.shape[axis] == 0 else None


# (regex on '/'-joined path, spec template per trailing dims)
# templates use 'D' -> data, 'M' -> model, '.' -> replicated; leading stack
# dims ('n') are always replicated.
_PARAM_RULES = [
    (r"embed$",                 ("M", "D")),
    (r"lm_head$",               ("D", "M")),
    (r"vision_proj$",           (".", "D")),
    (r"(wq|wq_x)$",             (".", "D", "M")),
    (r"(wk|wv|wk_x|wv_x)$",     (".", "D", "M")),
    (r"(wo|wo_x)$",             (".", "M", "D")),
    (r"(wi|wg)$",               (".", "D", "M")),
    (r"wo_ff$",                 (".", "M", "D")),
    (r"moe/router$",            (".", "D", ".")),
    # experts over "model" (aligns with the token-side dispatch layout so
    # no (data<->model) transpose of the dispatch buffer is ever needed);
    # expert d_model over "data" = FSDP, re-gathered per layer in the scan
    (r"moe/we_(gate|up)$",      (".", "M", "D", ".")),
    (r"moe/we_down$",           (".", "M", ".", "D")),
    (r"moe/ws_(gate|up)$",      (".", "D", "M")),
    (r"moe/ws_down$",           (".", "M", "D")),
    (r"w_dq$",                  (".", "D", ".")),
    (r"w_uq$",                  (".", ".", "M")),
    (r"w_dkv$",                 (".", "D", ".")),
    (r"(w_uk|w_uv)$",           (".", ".", "M")),
    (r"w_o$",                   (".", "M", "D")),
    (r"in_proj$",               (".", "D", ".")),
    (r"out_proj$",              (".", ".", "D")),
    (r"(w_gate|w_x)$",          (".", "D", "M")),
    (r"(w_a|w_i)$",             (".", "M", "M")),   # second M never fits twice -> repl
    (r"w_o$",                   (".", "M", "D")),
    (r"mtp/proj$",              ("D", ".")),
    (r"(A)$",                   (".", ".")),        # LoRA A: replicated
    (r"(B)$",                   (".", "M")),        # LoRA B: vocab over model
]

_AXIS = {"D": ("data",), "M": ("model",), "DM": ("data", "model"), ".": ()}


def _spec_for(path: str, shape, mesh: Mesh) -> P:
    for pat, tmpl in _PARAM_RULES:
        if re.search(pat, path):
            tmpl = tmpl[-len(shape):] if len(tmpl) >= len(shape) else \
                (".",) * (len(shape) - len(tmpl)) + tuple(tmpl)
            used = set()
            spec = []
            for dim, t in zip(shape, tmpl):
                choice = None
                # try the template's axes jointly, then prefixes, then none
                cand = [a for a in _AXIS[t]
                        if a in mesh.axis_names and a not in used]
                while cand:
                    size = 1
                    for a in cand:
                        size *= mesh.shape[a]
                    if dim % size == 0:
                        choice = tuple(cand) if len(cand) > 1 else cand[0]
                        used.update(cand)
                        break
                    cand = cand[:-1]
                spec.append(choice)
            return P(*spec)
    return P()           # norms, biases, scalars, conv weights, lambdas ...


def _path_str(path) -> str:
    return "/".join(str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
                    for p in path)


def param_specs(tree, mesh: Mesh):
    """Pytree of PartitionSpec matching `tree` (arrays or ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(_path_str(path), leaf.shape, mesh), tree)


def param_shardings(tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(tree, mesh),
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activations / caches
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh, batch: int, include_model: bool = False):
    """Largest prefix of ("pod","data"[,"model"]) that divides `batch`.

    include_model=True is the pure-FSDP training layout: the DVI train step
    has no backbone backward, so spending the model axis on batch (and
    gathering weights per layer) beats Megatron-style TP whose activation
    all-reduces dominate (EXPERIMENTS.md §Perf H4)."""
    names = ("pod", "data", "model") if include_model else ("pod", "data")
    axes = [a for a in names if a in mesh.axis_names]
    total = 1
    use = []
    for a in axes:
        if batch % (total * mesh.shape[a]) == 0:
            use.append(a)
            total *= mesh.shape[a]
    return tuple(use) if use else None


def tokens_spec(mesh: Mesh, batch: int, include_model: bool = False) -> P:
    return P(batch_axes(mesh, batch, include_model), None)


def cache_specs(cfg: ModelConfig, cache_tree, mesh: Mesh,
                seq_axis: Optional[str] = "model"):
    """Specs for the decode cache pytree.

    attention k/v (n, B, S, KV, hd): batch over data axes, S over `seq_axis`
    (flash-decode sequence sharding); MLA latents (n, B, S, r) likewise;
    stateful conv/ssd states: batch over data axes only."""
    def spec(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        if name.endswith("lengths") or name.endswith("pos"):
            return P()
        b_ax = None
        s_ax = None
        if len(shape) >= 2:
            b_ax = batch_axes(mesh, shape[1])
        if name.endswith(("/k", "/v")) and len(shape) == 5:
            s_ax = _fit(shape[2], seq_axis, mesh)
            return P(None, b_ax, s_ax, None, None)
        if name.endswith(("/ks", "/vs")) and len(shape) == 4:
            s_ax = _fit(shape[2], seq_axis, mesh)
            return P(None, b_ax, s_ax, None)
        if name.endswith(("ckv", "krope")) and len(shape) == 4:
            s_ax = _fit(shape[2], seq_axis, mesh)
            return P(None, b_ax, s_ax, None)
        if name.endswith(("xk", "xv")) and len(shape) == 5:
            return P(None, b_ax, None, _fit(shape[3], "model", mesh), None)
        # stateful: conv (n,B,cw-1,c) / state (n,B,...)
        return P(*([None, b_ax] + [None] * (len(shape) - 2)))
    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def constrain_cache_tree(cfg: ModelConfig, cache):
    """with_sharding_constraint the whole cache pytree to its canonical
    specs (no-op outside a mesh context) — keeps prefill-produced and
    decode-updated caches sequence/batch-sharded through jit boundaries."""
    from repro.launch import hints
    mesh = hints._MESH
    if mesh is None or not getattr(mesh, "axis_names", None):
        return cache
    specs = cache_specs(cfg, cache, mesh)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s)),
        cache, specs)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
