"""OpenAI-compatible API server over the DVI serving engine.

Builds the tiny-backbone serving stack (``serving.config.ModelSpec``
recipe: init -> synthetic pretrain -> online trainer state), runs the
engine on a dedicated thread (``serving.http.EngineDriver``) and serves:

  POST /v1/completions   (``"stream": true`` -> SSE)
  GET  /v1/models
  GET  /metrics          (Prometheus text)
  GET  /healthz

Prompts are token-id lists — this repo serves a synthetic vocab:

  PYTHONPATH=src python -m repro.launch.api_server --port 8000 --tiny \\
      --kv-pages 64 --prefix-cache --prefill-chunk 8 &
  curl -N localhost:8000/v1/completions -d \\
      '{"prompt": [3, 17, 42], "max_tokens": 16, "stream": true}'

Graceful shutdown (SIGTERM/SIGINT): stop accepting connections, join
in-flight handler threads (the engine keeps stepping, so open SSE
streams run to completion), drain the engine, exit 0 — asserted by CI.
"""
from __future__ import annotations

import argparse
import signal
import sys
import threading

from repro.serving.config import (EngineConfig, ModelSpec,
                                  build_engine, build_model_bundle)
from repro.serving.http import ApiServer, EngineDriver


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000)
    ap.add_argument("--request-timeout", type=float, default=300.0,
                    help="per-request (and per-SSE-chunk) wait bound")
    ModelSpec.add_args(ap)
    EngineConfig.add_args(ap, EngineConfig(max_new=32))
    args = ap.parse_args(argv)
    spec = ModelSpec.from_args(args)
    econf = EngineConfig.from_args(args)

    print(f"[api] building model: arch={spec.arch} tiny={spec.tiny} "
          f"seed={spec.seed} pretrain_steps={spec.pretrain_steps}",
          flush=True)
    _cfg, model, params, _tasks, state = build_model_bundle(spec)
    engine = build_engine(econf, model, params, state)
    driver = EngineDriver(engine).start()
    srv = ApiServer((args.host, args.port), driver,
                    model_id=f"{spec.arch}{'-tiny' if spec.tiny else ''}",
                    default_max_new=econf.max_new,
                    request_timeout_s=args.request_timeout)

    def _shutdown(signum, frame):
        # shutdown() must not run on the serve_forever thread; hand it off
        print(f"[api] signal {signum}: draining...", flush=True)
        threading.Thread(target=srv.shutdown, daemon=True).start()

    signal.signal(signal.SIGTERM, _shutdown)
    signal.signal(signal.SIGINT, _shutdown)

    print(f"[api] serving on http://{args.host}:{args.port} "
          f"(scheduler={econf.scheduler}, slots={econf.num_slots}, "
          f"max_queue={econf.max_queue or 'unbounded'})", flush=True)
    try:
        srv.serve_forever(poll_interval=0.1)
    finally:
        # order matters: close the listener and JOIN in-flight handler
        # threads FIRST (non-daemon; the driver is still stepping, so open
        # streams finish), THEN drain + stop the engine thread
        srv.server_close()
        driver.stop(drain=True)
    print("[api] drained; exit 0", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
