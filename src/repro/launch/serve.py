"""Serving launcher: continual-learning speculative serving demo.

Streams synthetic requests (optionally with a mid-run task-distribution
shift) through the ServingEngine and reports acceptance / MAT / latency —
the paper's deployment story end-to-end on CPU with a tiny backbone.

Engine knobs (scheduler, slots, paged KV, prefix cache, adaptive K,
telemetry, ...) come from the shared ``serving.config.EngineConfig`` flag
set; the backbone recipe from ``ModelSpec`` — both shared with
``launch.api_server`` and ``benchmarks/``.  Launcher-specific flags:

  --requests N       how many synthetic requests to stream
  --prompt-len L     synthetic prompt length (also the sync-path bucket)
  --shift-at N       switch task category after N requests (drift demo)
  --trace-out PATH   write the Chrome/Perfetto lifecycle trace
  --metrics-out PATH write the final metrics snapshot (.json or .prom)

  PYTHONPATH=src python -m repro.launch.serve --arch vicuna-7b --tiny \\
      --requests 64 --shift-at 32 --scheduler continuous --num-slots 8
"""
from __future__ import annotations

import argparse
import time

from repro.serving.config import (EngineConfig, ModelSpec, build_engine,
                                  build_model_bundle)
from repro.serving.engine import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--shift-at", type=int, default=0,
                    help="switch task category after N requests (drift demo)")
    ap.add_argument("--trace-out", default=None,
                    help="write the Chrome/Perfetto trace JSON here "
                         "(implies --telemetry)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final metrics snapshot here (.json = "
                         "snapshot JSON, else Prometheus text format)")
    ModelSpec.add_args(ap)
    EngineConfig.add_args(ap, EngineConfig(max_new=24))
    args = ap.parse_args()
    spec = ModelSpec.from_args(args)
    econf = EngineConfig.from_args(args)
    econf.bucket = args.prompt_len      # sync path: bucket == prompt length
    if args.trace_out:
        econf.telemetry = True

    cfg, model, params, tasks, state = build_model_bundle(spec)
    eng = build_engine(econf, model, params, state)
    t0 = time.monotonic()
    done, handles = [], []
    for i in range(args.requests):
        cat = "qa" if (not args.shift_at or i < args.shift_at) else "math"
        prompt = tasks.sample(cat, 1, args.prompt_len, seed=1000 + i)[0]
        handles.append(eng.submit_request(
            Request(uid=i, prompt=prompt, max_new=econf.max_new)))
        if (i + 1) % econf.batch_size == 0:
            done.extend(eng.step())
            mat = done[-1].mat if done else 0.0
            print(f"[serve] {i+1:4d} reqs  acceptance={eng.acceptance:.3f} "
                  f"MAT={mat:.2f}  updates={eng.stats['updates']}")
    done.extend(eng.run())
    dt = time.monotonic() - t0
    toks = sum(len(c.gen_tokens) for c in done)
    lat = eng.latency_percentiles()
    print(f"[serve] {len(done)} completions, {toks} gen tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s); final acceptance={eng.acceptance:.3f}; "
          f"latency p50={lat['p50_s']:.2f}s p95={lat['p95_s']:.2f}s")
    # handle timestamps split each request's wall time into phases (the
    # old Completion.latency_s only had the lump sum)
    spans = [h.timings() for h in handles if h.finished]
    if spans:
        n = len(spans)
        mean = lambda k: sum(s[k] or 0.0 for s in spans) / n  # noqa: E731
        print(f"[serve] request phases (mean over {n}): "
              f"queue_wait={mean('queue_wait_s')*1e3:.0f}ms "
              f"prefill={mean('prefill_s')*1e3:.0f}ms "
              f"decode={mean('decode_s')*1e3:.0f}ms "
              f"ttft={mean('ttft_s')*1e3:.0f}ms "
              f"e2e={mean('e2e_s')*1e3:.0f}ms")
    if econf.scheduler == "continuous":
        d = eng.dispatch_stats()
        print(f"[serve] dispatch: sync_every={d['sync_every']} "
              f"host_syncs/100blk={d['host_syncs_per_100_blocks']:.1f} "
              f"host_wait={d['host_wait_s']:.2f}s "
              f"dispatches={d['dispatches']}")
        if econf.prefill_chunk:
            tk = eng.tick_percentiles()
            print(f"[serve] chunked prefill: chunk={d['prefill_chunk']} "
                  f"chunk_steps={d['prefill_chunks']} "
                  f"prefill_tokens={d['prefill_tokens']} "
                  f"max_tick_prefill_tokens={d['max_tick_prefill_tokens']} "
                  f"tick p50={tk['p50_s']*1e3:.0f}ms "
                  f"p95={tk['p95_s']*1e3:.0f}ms max={tk['max_s']*1e3:.0f}ms")
    if econf.kv_pages:
        kv = eng.kv_stats()
        print(f"[serve] paged KV: peak_util={kv['peak_utilization']:.2f} "
              f"preemptions={kv['preemptions']} "
              f"peak_live={kv['peak_live_slots']}")
        if econf.prefix_cache:
            print(f"[serve] prefix cache: hits={kv['prefix_hits']}/"
                  f"{kv['prefix_lookups']} lookups, "
                  f"tokens_spliced={kv['prefix_hit_tokens']} "
                  f"cow={eng.stats['prefix_cow_copies']} "
                  f"evictions={kv['prefix_evictions']} "
                  f"cached_pages={kv['cached_pages']} "
                  f"indexed={kv['indexed_pages']}")
    if econf.adaptive_k:
        ak = eng.adaptive_stats()
        print(f"[serve] adaptive K in [{ak['k_min']},{ak['k_max']}]: "
              f"mean_depth={ak['mean_depth']:.2f} "
              f"recent={ak['k_mean_recent']:.2f} "
              f"draft_efficiency={ak['draft_efficiency']:.2f} "
              f"k_lane={ak['k_lane'].tolist()}")
    if econf.learn and econf.scheduler == "continuous":
        tt = eng.train_telemetry()
        if tt["updates"]:
            print(f"[serve] DVI train: updates={tt['updates']} "
                  f"step={tt['step']} phase={tt['phase_name']} "
                  f"loss={tt['loss']:.4f} kl={tt['loss_kl']:.4f} "
                  f"ce={tt['loss_ce']:.4f} pg={tt['loss_pg']:.4f} "
                  f"acc_ema {tt['acceptance_ema_before']:.3f}->"
                  f"{tt['acceptance_ema_after']:.3f}")
    if args.trace_out:
        eng.write_trace(args.trace_out)
        print(f"[serve] trace written to {args.trace_out} "
              f"(open in Perfetto / chrome://tracing)")
    if args.metrics_out:
        eng.write_metrics(args.metrics_out)
        print(f"[serve] metrics written to {args.metrics_out}")


if __name__ == "__main__":
    main()
