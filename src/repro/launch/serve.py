"""Serving launcher: continual-learning speculative serving demo.

Streams synthetic requests (optionally with a mid-run task-distribution
shift) through the ServingEngine and reports acceptance / MAT / latency —
the paper's deployment story end-to-end on CPU with a tiny backbone.

Two schedulers (``--scheduler``):

* ``continuous`` (default) — slot-based continuous batching: ``--num-slots``
  lanes over one persistent cache, per-request prefill-on-arrival and
  per-request retirement, drafter updates on a block-step cadence.
* ``sync`` — legacy batch-synchronous path (bucket, pad, decode the whole
  batch to completion) for comparison.

``--kv-pages N`` (with ``--kv-page-size``) switches the continuous
scheduler onto the paged KV pool: admission is gated on free pages instead
of worst-case slot reservations, and the engine preempts-or-queues when
the pool runs dry (see repro.serving.kv_pool).

``--prefix-cache`` (paged mode, with ``--prefill-chunk``) shares
page-aligned prompt prefixes across requests through a content-hash index
over the pool: repeated system prompts are spliced into a new lane's block
table by refcount instead of re-prefilled, partially-filled tail pages are
copied-on-write, and refcount-0 cached pages are evicted LRU only under
pressure.  Committed streams are bit-identical to cold prefill.

``--adaptive-k`` turns speculation depth into a per-lane runtime quantity
steered by each lane's acceptance EMA (see repro.core.schedule): greedy
token streams are unchanged, but lanes with poor acceptance throttle their
draft depth (and the whole batch drafts shallower once every lane has),
recovering draft compute and KV-pool headroom under drift.

  PYTHONPATH=src python -m repro.launch.serve --arch vicuna-7b --tiny \\
      --requests 64 --shift-at 32 --scheduler continuous --num-slots 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import online as online_mod
from repro.data import SyntheticTasks, TASK_CATEGORIES
from repro.models.model import build_model
from repro.serving import Request, ServingEngine
from repro.training import pretrain


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vicuna-7b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--scheduler", choices=("sync", "continuous"),
                    default="continuous")
    ap.add_argument("--num-slots", type=int, default=8,
                    help="decode lanes for the continuous scheduler")
    ap.add_argument("--sync-every", type=int, default=1,
                    help="speculative blocks fused per device sync "
                         "(continuous scheduler superstep size; admission/"
                         "retirement happen at superstep boundaries)")
    ap.add_argument("--kv-pages", type=int, default=0,
                    help=">0: paged KV cache with this many pool pages")
    ap.add_argument("--kv-page-size", type=int, default=16,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help=">0: prefill prompts in chunks of this many tokens "
                         "interleaved with decode supersteps (bounds "
                         "block-step jitter under long prompts; streams "
                         "stay bit-identical to one-shot prefill)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged mode: content-address page-aligned prompt "
                         "prefixes so repeated system prompts are spliced "
                         "from the pool (refcount sharing + copy-on-write "
                         "tails) instead of re-prefilled; needs --kv-pages "
                         "and --prefill-chunk (streams stay bit-identical "
                         "to cold prefill)")
    ap.add_argument("--adaptive-k", action="store_true",
                    help="per-lane acceptance-driven speculation depth: "
                         "each lane's K adapts in [k-min, k-max] from its "
                         "accept/reject EMA (greedy streams are unchanged; "
                         "draft compute shrinks where acceptance is low)")
    ap.add_argument("--k-min", type=int, default=1,
                    help="adaptive-k depth floor")
    ap.add_argument("--k-max", type=int, default=0,
                    help="adaptive-k depth ceiling (0 = cfg k_spec)")
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--shift-at", type=int, default=0,
                    help="switch task category after N requests (drift demo)")
    ap.add_argument("--no-learn", action="store_true")
    ap.add_argument("--pretrain-steps", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry", action="store_true",
                    help="record the per-request lifecycle trace (metrics "
                         "registry is always on; adds zero host syncs)")
    ap.add_argument("--trace-out", default=None,
                    help="write the Chrome/Perfetto trace JSON here "
                         "(implies --telemetry)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final metrics snapshot here (.json = "
                         "snapshot JSON, else Prometheus text format)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace of the first "
                         "dispatches into this directory")
    args = ap.parse_args()
    if args.trace_out:
        args.telemetry = True

    cfg = get_config(args.arch, tiny=args.tiny).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    tasks = SyntheticTasks(cfg.vocab_size, seed=args.seed)
    params, _ = pretrain(model, params,
                         tasks.stream(TASK_CATEGORIES, args.pretrain_steps,
                                      8, 32, seed=args.seed + 1), lr=2e-3)
    state = online_mod.init_trainer(model, jax.random.PRNGKey(args.seed + 7))
    eng = ServingEngine(model, params, state, scheduler=args.scheduler,
                        num_slots=args.num_slots, batch_size=args.batch,
                        max_new=args.max_new, learn=not args.no_learn,
                        buckets=(args.prompt_len,), kv_pages=args.kv_pages,
                        kv_page_size=args.kv_page_size,
                        sync_every=args.sync_every,
                        prefill_chunk=args.prefill_chunk,
                        prefix_cache=args.prefix_cache,
                        adaptive_k=args.adaptive_k, k_min=args.k_min,
                        k_max=args.k_max, telemetry=args.telemetry,
                        profile_dir=args.profile_dir)
    t0 = time.monotonic()
    done = []
    for i in range(args.requests):
        cat = "qa" if (not args.shift_at or i < args.shift_at) else "math"
        prompt = tasks.sample(cat, 1, args.prompt_len, seed=1000 + i)[0]
        eng.submit(Request(uid=i, prompt=prompt, max_new=args.max_new))
        if (i + 1) % args.batch == 0:
            done.extend(eng.step())
            mat = done[-1].mat if done else 0.0
            print(f"[serve] {i+1:4d} reqs  acceptance={eng.acceptance:.3f} "
                  f"MAT={mat:.2f}  updates={eng.stats['updates']}")
    done.extend(eng.run())
    dt = time.monotonic() - t0
    toks = sum(len(c.gen_tokens) for c in done)
    lat = eng.latency_percentiles()
    print(f"[serve] {len(done)} completions, {toks} gen tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s); final acceptance={eng.acceptance:.3f}; "
          f"latency p50={lat['p50_s']:.2f}s p95={lat['p95_s']:.2f}s")
    if args.scheduler == "continuous":
        d = eng.dispatch_stats()
        print(f"[serve] dispatch: sync_every={d['sync_every']} "
              f"host_syncs/100blk={d['host_syncs_per_100_blocks']:.1f} "
              f"host_wait={d['host_wait_s']:.2f}s "
              f"dispatches={d['dispatches']}")
        if args.prefill_chunk:
            tk = eng.tick_percentiles()
            print(f"[serve] chunked prefill: chunk={d['prefill_chunk']} "
                  f"chunk_steps={d['prefill_chunks']} "
                  f"prefill_tokens={d['prefill_tokens']} "
                  f"max_tick_prefill_tokens={d['max_tick_prefill_tokens']} "
                  f"tick p50={tk['p50_s']*1e3:.0f}ms "
                  f"p95={tk['p95_s']*1e3:.0f}ms max={tk['max_s']*1e3:.0f}ms")
    if args.kv_pages:
        kv = eng.kv_stats()
        print(f"[serve] paged KV: peak_util={kv['peak_utilization']:.2f} "
              f"preemptions={kv['preemptions']} "
              f"peak_live={kv['peak_live_slots']}")
        if args.prefix_cache:
            print(f"[serve] prefix cache: hits={kv['prefix_hits']}/"
                  f"{kv['prefix_lookups']} lookups, "
                  f"tokens_spliced={kv['prefix_hit_tokens']} "
                  f"cow={eng.stats['prefix_cow_copies']} "
                  f"evictions={kv['prefix_evictions']} "
                  f"cached_pages={kv['cached_pages']} "
                  f"indexed={kv['indexed_pages']}")
    if args.adaptive_k:
        ak = eng.adaptive_stats()
        print(f"[serve] adaptive K in [{ak['k_min']},{ak['k_max']}]: "
              f"mean_depth={ak['mean_depth']:.2f} "
              f"recent={ak['k_mean_recent']:.2f} "
              f"draft_efficiency={ak['draft_efficiency']:.2f} "
              f"k_lane={ak['k_lane'].tolist()}")
    if not args.no_learn and args.scheduler == "continuous":
        tt = eng.train_telemetry()
        if tt["updates"]:
            print(f"[serve] DVI train: updates={tt['updates']} "
                  f"step={tt['step']} phase={tt['phase_name']} "
                  f"loss={tt['loss']:.4f} kl={tt['loss_kl']:.4f} "
                  f"ce={tt['loss_ce']:.4f} pg={tt['loss_pg']:.4f} "
                  f"acc_ema {tt['acceptance_ema_before']:.3f}->"
                  f"{tt['acceptance_ema_after']:.3f}")
    if args.trace_out:
        eng.write_trace(args.trace_out)
        print(f"[serve] trace written to {args.trace_out} "
              f"(open in Perfetto / chrome://tracing)")
    if args.metrics_out:
        eng.write_metrics(args.metrics_out)
        print(f"[serve] metrics written to {args.metrics_out}")


if __name__ == "__main__":
    main()
