"""Multi-host / multi-pod runtime initialization (production boilerplate).

On real TPU pods each host runs the same program; ``init_runtime()`` wires
jax.distributed from the standard environment (GKE/TPU-VM style) and
returns the global mesh.  On CPU (this container) it no-ops and the caller
falls back to the 512-fake-device dry-run path.

Typical pod launch (one line per host, or via GKE jobset):

    COORDINATOR_ADDRESS=$LEADER:8476 NUM_PROCESSES=$N PROCESS_ID=$i \\
        python -m repro.launch.train --arch llama3-405b --mode dvi-batch ...
"""
from __future__ import annotations

import os

import jax

from repro.launch.mesh import make_production_mesh


def init_runtime(require_tpu: bool = False):
    """Initialize jax.distributed if a coordinator is configured."""
    coord = os.environ.get("COORDINATOR_ADDRESS")
    nproc = os.environ.get("NUM_PROCESSES")
    pid = os.environ.get("PROCESS_ID")
    if coord and nproc and pid is not None:
        jax.distributed.initialize(coordinator_address=coord,
                                   num_processes=int(nproc),
                                   process_id=int(pid))
    if require_tpu and jax.default_backend() != "tpu":
        raise RuntimeError(
            f"TPU required, got backend={jax.default_backend()!r}; "
            "use the dry-run path on CPU")
    return jax.devices()


def production_mesh_or_dryrun():
    """Real mesh on a pod; on CPU, instruct the caller to use dryrun.py."""
    n = len(jax.devices())
    if n >= 512:
        return make_production_mesh(multi_pod=True)
    if n >= 256:
        return make_production_mesh(multi_pod=False)
    raise RuntimeError(
        f"{n} devices < 256: not a production slice. For configuration "
        "validation run `python -m repro.launch.dryrun` (forces 512 host "
        "devices before jax init).")
