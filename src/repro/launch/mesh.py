"""Production mesh construction (TPU v5e).

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is pure data parallelism crossing DCN.

Defined as functions (never module-level constants) so importing this module
never touches jax device state; the dry-run alone forces 512 host devices
via XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CI-scale sharding tests (requires >= n_data*n_model
    host devices via --xla_force_host_platform_device_count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
