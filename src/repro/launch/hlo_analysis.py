"""Structural HLO analysis with while-loop trip-count accounting.

``compiled.cost_analysis()`` (and naive text grepping) counts each while-loop
body ONCE — but our stacks scan over layers and flash-attention chunks, so
real per-device FLOPs/collective-bytes are body-cost x trip-count.  This
module parses the post-SPMD HLO text into computations, extracts while-loop
trip counts (canonical `compare(iv, constant(N)), direction=LT` conditions),
and propagates multipliers through the call graph to give:

* matmul FLOPs per device (from `dot` ops: 2 * |out| * contracted size)
* collective payload / estimated wire bytes per device, per kind

This is the §Perf profiling tool: it reads the same artifact a TPU run
would compile.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8,
                "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DOT_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shapes(s: str):
    return [(dt, [int(x) for x in dims.split(",")] if dims else [])
            for dt, dims in _SHAPE_RE.findall(s)]


def _bytes_of(s: str) -> int:
    total = 0
    for dt, dims in _shapes(s):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclass
class Op:
    name: str
    out_type: str
    kind: str
    line: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    defs: Dict[str, str] = field(default_factory=dict)   # op name -> out type


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        mc = _COMP_RE.match(line)
        if mc and ("{" in line):
            cur = Computation(mc.group(1))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if mo:
            op = Op(mo.group(1), mo.group(2), mo.group(3), line.rstrip())
            cur.ops.append(op)
            cur.defs[op.name] = op.out_type
        if line.strip() == "}":
            cur = None
    return comps


def _trip_count(cond: Computation) -> int:
    """Trip count from the loop condition: the constant bound feeding the
    ROOT comparison (possibly wrapped in a fusion) — canonical lax.scan
    lowering compares the induction variable (from 0) against N via LT."""
    consts = {}
    root = None
    for op in cond.ops:
        m = _CONST_RE.search(op.line)
        if m:
            consts[op.name] = int(m.group(1))
        if "ROOT" in op.line:
            root = op
    if root is not None:
        for name, val in consts.items():
            if f"%{name}" in root.line:
                return max(val, 1)
    return max(consts.values(), default=1)


def _dot_flops(op: Op, comp: Computation) -> float:
    out_shapes = _shapes(op.out_type)
    out_elems = 1
    for _, dims in out_shapes:
        for d in dims:
            out_elems *= d
    # contracted size from the lhs operand's shape
    m = re.search(r"\(\s*%?([\w\.\-]+)", op.line[op.line.index(op.kind):])
    contract = 1
    md = _DOT_DIMS_RE.search(op.line)
    if m and md and md.group(1):
        lhs_type = comp.defs.get(m.group(1))
        if lhs_type:
            lshapes = _shapes(lhs_type)
            if lshapes:
                ldims = lshapes[0][1]
                for idx in md.group(1).split(","):
                    i = int(idx)
                    if i < len(ldims):
                        contract *= ldims[i]
    return 2.0 * out_elems * contract


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return max(int(m.group(2)), 2)
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(len(m.group(1).split(",")), 2)
    return 2


def _wire_bytes(kind: str, out_bytes: float, gsize: int) -> float:
    frac = (gsize - 1) / gsize
    if kind == "all-reduce":
        return 2 * out_bytes * frac
    if kind == "all-gather":
        return out_bytes * frac
    if kind == "reduce-scatter":
        return out_bytes * (gsize - 1)
    if kind == "all-to-all":
        return out_bytes * frac
    return out_bytes      # collective-permute


def analyze(hlo: str, entry: Optional[str] = None) -> dict:
    """Trip-count-weighted per-device FLOPs + collective schedule."""
    comps = parse_computations(hlo)
    entry_name = entry
    if entry_name is None:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
        entry_name = m.group(1) if m else next(iter(comps))

    memo: Dict[str, dict] = {}

    def walk(name: str, depth=0) -> dict:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        agg = {"dot_flops": 0.0, "collectives": {}}
        if comp is None or depth > 32:
            return agg
        memo[name] = agg   # provisional (cycles)
        for op in comp.ops:
            if op.kind == "dot":
                agg["dot_flops"] += _dot_flops(op, comp)
            else:
                kind = op.kind[:-6] if op.kind.endswith("-start") else op.kind
                if kind in COLLECTIVES:
                    out_b = _bytes_of(op.out_type)
                    g = _group_size(op.line)
                    d = agg["collectives"].setdefault(
                        kind, {"count": 0.0, "payload_bytes": 0.0,
                               "wire_bytes": 0.0})
                    d["count"] += 1
                    d["payload_bytes"] += out_b
                    d["wire_bytes"] += _wire_bytes(kind, out_b, g)
            if op.kind == "while":
                mw = _WHILE_RE.search(op.line)
                if mw:
                    trips = _trip_count(comps.get(mw.group(1), Computation("")))
                    sub = walk(mw.group(2), depth + 1)
                    _merge(agg, sub, trips)
            elif op.kind in ("fusion", "call", "reduce", "map", "sort",
                             "scatter", "conditional", "custom-call"):
                mc = _CALL_RE.search(op.line)
                if mc and mc.group(1) in comps and op.kind in ("fusion", "call"):
                    sub = walk(mc.group(1), depth + 1)
                    _merge(agg, sub, 1)
        memo[name] = agg
        return agg

    def _merge(agg, sub, mult):
        agg["dot_flops"] += sub["dot_flops"] * mult
        for kind, d in sub["collectives"].items():
            t = agg["collectives"].setdefault(
                kind, {"count": 0.0, "payload_bytes": 0.0, "wire_bytes": 0.0})
            for k in t:
                t[k] += d[k] * mult

    agg = walk(entry_name)
    total = {"count": sum(d["count"] for d in agg["collectives"].values()),
             "payload_bytes": sum(d["payload_bytes"]
                                  for d in agg["collectives"].values()),
             "wire_bytes": sum(d["wire_bytes"]
                               for d in agg["collectives"].values())}
    return {"dot_flops_per_device": agg["dot_flops"],
            "collectives_per_kind": agg["collectives"],
            "collectives_total": total}
