"""Sweep driver: run every (arch x shape x mesh) dry-run as a subprocess
(one process per case — 512 fake devices + big HLO compiles stay isolated).

  PYTHONPATH=src python -m repro.launch.dryrun_all [--out experiments/dryrun]
      [--mesh single|multi|both] [--archs a,b,c] [--shapes s1,s2]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES

TIMEOUT_S = 3000


def run_one(arch: str, shape: str, multipod: bool, out: str) -> dict:
    tag = f"{arch}_{shape}_{'2x16x16' if multipod else '16x16'}"
    path = os.path.join(out, tag + ".json")
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") in ("ok", "skip"):
            print(f"[sweep] {tag}: cached ({rec['status']})")
            return rec
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", out]
    if multipod:
        cmd.append("--multipod")
    t0 = time.time()
    try:
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=TIMEOUT_S)
        ok = r.returncode == 0
        tail = (r.stdout + r.stderr).strip().splitlines()[-1:] or [""]
        print(f"[sweep] {tag}: {'ok' if ok else 'FAIL'} "
              f"({time.time()-t0:.0f}s) {tail[0][:150]}")
    except subprocess.TimeoutExpired:
        print(f"[sweep] {tag}: TIMEOUT after {TIMEOUT_S}s")
        with open(path, "w") as f:
            json.dump({"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if multipod else "16x16",
                       "status": "fail", "error": "compile timeout"}, f)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {"status": "fail", "arch": arch, "shape": shape}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--archs", default=",".join(ASSIGNED_ARCHS))
    ap.add_argument("--shapes", default=",".join(INPUT_SHAPES))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    archs = args.archs.split(",")
    shapes = args.shapes.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    results = []
    for shape in shapes:
        for arch in archs:
            for mp in meshes:
                results.append(run_one(arch, shape, mp, args.out))
    n_ok = sum(r.get("status") == "ok" for r in results)
    n_skip = sum(r.get("status") == "skip" for r in results)
    n_fail = len(results) - n_ok - n_skip
    print(f"[sweep] done: {n_ok} ok, {n_skip} skip, {n_fail} fail "
          f"of {len(results)}")


if __name__ == "__main__":
    main()
