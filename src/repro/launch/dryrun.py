"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

This is the proof that the distribution config is coherent without real
hardware: sharding mismatches, compile-time OOM, or unsupported collectives
all surface here as failures.  For each combination we record:

* memory_analysis()   — per-device argument/temp/output bytes (fits < 16 GB HBM?)
* cost_analysis()     — per-device HLO FLOPs / bytes accessed
* collective schedule — parsed from the post-SPMD HLO: per-kind op counts,
  payload bytes and estimated wire bytes per device (§Roofline inputs)

Run:  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \\
          --shape train_4k [--multipod] [--out experiments/dryrun]
"""
# The 512 placeholder devices MUST be forced before any jax import.
import os  # noqa: E402

_FLAG = "--xla_force_host_platform_device_count=512"
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import INPUT_SHAPES, get_config          # noqa: E402
from repro.configs.base import InputShape, ModelConfig      # noqa: E402
from repro.core import losses as losses_mod                 # noqa: E402
from repro.core import spec as spec_mod                     # noqa: E402
from repro.core.lora import init_draft_params               # noqa: E402
from repro.launch import sharding as shd                    # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.models.model import build_model                  # noqa: E402
from repro.optim import adamw_init                          # noqa: E402

# ---------------------------------------------------------------------------
# Shape adaptation policy (DESIGN.md §6)
# ---------------------------------------------------------------------------

SWA_FALLBACK = {"llama3-405b", "qwen2.5-14b", "qwen3-0.6b", "qwen3-1.7b",
                "vicuna-7b"}
LONG_NATIVE = {"mamba2-370m", "recurrentgemma-9b", "llama4-scout-17b-a16e"}
LONG_SKIP = {"deepseek-v3-671b": "pure full-attention (MLA); no SWA variant claimed",
             "paligemma-3b": "pure full-attention (gemma-1); no SWA variant",
             "whisper-large-v3": "enc-dec with 448-token decoder context"}


def adapt_config(arch: str, shape: InputShape, kv_quant: bool = False):
    """Returns (cfg, note) or (None, skip_reason)."""
    cfg = get_config(arch)
    note = ""
    if kv_quant:
        cfg = cfg.replace(kv_quant=True)
        note = "int8 KV cache variant (§Perf H5)"
    if shape.name == "long_500k":
        if arch in LONG_SKIP:
            return None, LONG_SKIP[arch]
        if arch in SWA_FALLBACK:
            cfg = cfg.replace(sliding_window=8192, global_attn_every=0)
            note = "sliding-window 8192 variant (not the paper config)"
    return cfg, note


def make_aux_specs(cfg: ModelConfig, B: int):
    aux = {}
    if cfg.vision is not None:
        aux["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision.num_patches, cfg.vision.d_embed), jnp.float32)
    if cfg.encoder is not None:
        aux["frame_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder.num_frames, cfg.encoder.d_model or cfg.d_model),
            jnp.float32)
    return aux or None


# ---------------------------------------------------------------------------
# Step construction per shape kind
# ---------------------------------------------------------------------------

def build_case(cfg: ModelConfig, shape: InputShape, mesh):
    """Returns (fn, arg_shapes:list, in_shardings:list, out_spec_fn)."""
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    key = jax.random.PRNGKey(0)
    param_shapes = jax.eval_shape(lambda: model.init(key))
    p_shard = shd.to_shardings(shd.param_specs(param_shapes, mesh), mesh)
    dvi_shapes = jax.eval_shape(lambda: init_draft_params(key, cfg))
    dvi_shard = shd.to_shardings(shd.param_specs(dvi_shapes, mesh), mesh)
    repl = shd.replicated(mesh)
    aux_specs = make_aux_specs(cfg, B)
    aux_shard = None if aux_specs is None else jax.tree.map(
        lambda _: NamedSharding(mesh, shd.tokens_spec(mesh, B)), aux_specs)

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(lambda: adamw_init(dvi_shapes))
        # Adam m/v mirror the dvi tree leaf-for-leaf: reuse the dvi specs
        opt_shard = {"m": dvi_shard, "v": dvi_shard,
                     "step": NamedSharding(mesh, P())}
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        tok_shard = NamedSharding(mesh, shd.tokens_spec(mesh, B,
                                                        include_model=True))
        from repro.optim import adamw_update

        def fn(params, dvi_params, opt_state, tokens, aux):
            def loss_fn(dp):
                return losses_mod.dense_train_losses(
                    model, params, dp, tokens, jnp.int32(100),
                    jnp.float32(0.5), "full", aux, remat=True)
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(dvi_params)
            dvi2, opt2, _ = adamw_update(dvi_params, grads, opt_state, 1e-3)
            return dvi2, opt2, loss

        args = [param_shapes, dvi_shapes, opt_shapes, tokens, aux_specs]
        shards = [p_shard, dvi_shard, opt_shard, tok_shard, aux_shard]
        return fn, args, shards

    if shape.kind == "prefill":
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        tok_shard = NamedSharding(mesh, shd.tokens_spec(mesh, B))

        P_extra = cfg.vision.num_patches if cfg.vision is not None else 0

        cap = -(-(S + P_extra + cfg.dvi.k_spec + 8) // 256) * 256

        def fn(params, dvi_params, tokens, aux):
            h, cache, _ = model.prefill(params, tokens, aux, max_len=cap)
            cache = shd.constrain_cache_tree(cfg, cache)
            from repro.core.lora import draft_logits
            vlog = model.logits(params, h[:, -1])
            dlog = draft_logits(model, params, dvi_params, h[:, -1])
            return jnp.argmax(vlog, -1), jnp.argmax(dlog, -1), cache

        args = [param_shapes, dvi_shapes, tokens, aux_specs]
        shards = [p_shard, dvi_shard, tok_shard, aux_shard]
        return fn, args, shards

    # decode: one DVI speculative serve step against a seq_len cache
    # (capacity rounded to a mesh-divisible multiple so the sequence dim
    # shards: 32780 % 16 != 0 would silently replicate a 2 TB cache)
    cache_cap = -(-(S + cfg.dvi.k_spec + 8) // 256) * 256
    cache_shapes = jax.eval_shape(lambda: model.init_cache(B, cache_cap))
    c_shard = shd.to_shardings(shd.cache_specs(cfg, cache_shapes, mesh), mesh)
    pending = jax.ShapeDtypeStruct((B,), jnp.int32)
    pend_shard = NamedSharding(mesh, P(shd.batch_axes(mesh, B)))

    def fn(params, dvi_params, pending, cache):
        # mark the cache as "full": lengths = S (committed tokens)
        cache = dict(cache, lengths=jnp.full((B,), S, jnp.int32))
        y, commit_vec, accept, cache2 = spec_mod.serve_step(
            model, params, dvi_params, pending, cache)
        return y, commit_vec, accept, shd.constrain_cache_tree(cfg, cache2)

    fn.donate = (3,)       # cache updates in place (real serving aliases it)
    args = [param_shapes, dvi_shapes, pending, cache_shapes]
    shards = [p_shard, dvi_shard, pend_shard, c_shard]
    return fn, args, shards


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8,
                "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[\d,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _type_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_collectives(hlo: str):
    per_kind = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        out_bytes = _type_bytes(m.group(1))
        kind = m.group(2)
        gm = _GROUPS_RE.search(line)
        if gm:
            gsize = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            gsize = len(gl.group(1).split(",")) if gl else 2
        gsize = max(gsize, 2)
        frac = (gsize - 1) / gsize
        if kind == "all-reduce":
            wire = 2 * out_bytes * frac
        elif kind == "all-gather":
            wire = out_bytes * frac        # received bytes per device
        elif kind == "reduce-scatter":
            wire = out_bytes * (gsize - 1) # input = out * gsize
        elif kind == "all-to-all":
            wire = out_bytes * frac
        else:                              # collective-permute
            wire = out_bytes
        d = per_kind.setdefault(kind, {"count": 0, "payload_bytes": 0.0,
                                       "wire_bytes": 0.0})
        d["count"] += 1
        d["payload_bytes"] += out_bytes
        d["wire_bytes"] += wire
    total = {"count": sum(d["count"] for d in per_kind.values()),
             "payload_bytes": sum(d["payload_bytes"] for d in per_kind.values()),
             "wire_bytes": sum(d["wire_bytes"] for d in per_kind.values())}
    return per_kind, total


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_case(arch: str, shape_name: str, multi_pod: bool,
             hlo_dir: str | None = None, kv_quant: bool = False) -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg, note = adapt_config(arch, shape, kv_quant)
    rec = {"arch": arch + ("+kvq" if kv_quant else ""), "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "note": note}
    if cfg is None:
        rec["status"] = "skip"
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    from repro.launch.hints import set_hint_mesh
    set_hint_mesh(mesh)
    fn, args, shards = build_case(cfg, shape, mesh)
    donate = getattr(fn, "donate", ())
    with mesh:
        lowered = jax.jit(fn, in_shardings=shards,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
    per_kind, total = parse_collectives(hlo)
    from repro.launch import hlo_analysis
    deep = hlo_analysis.analyze(hlo)
    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "n_devices": mesh.size,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_bytes": (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                           + ma.output_size_in_bytes - ma.alias_size_in_bytes),
        },
        "cost": {
            # raw XLA numbers (NOTE: while-loop bodies counted ONCE — see
            # hlo_analysis docstring; use the trip-weighted numbers below)
            "xla_flops_per_device": ca.get("flops", 0.0),
            "xla_bytes_accessed_per_device": ca.get("bytes accessed", 0.0),
            "transcendentals": ca.get("transcendentals", 0.0),
            # trip-count-weighted matmul flops (per device)
            "dot_flops_per_device": deep["dot_flops_per_device"],
        },
        "collectives": {
            "per_kind": deep["collectives_per_kind"],
            "total": deep["collectives_total"],
            "static_per_kind": per_kind,    # per-HLO-occurrence (un-weighted)
        },
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    })
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{rec['mesh']}"
        with open(os.path.join(hlo_dir, tag + ".hlo.txt"), "w") as f:
            f.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(INPUT_SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache variant (EXPERIMENTS.md §Perf H5)")
    args = ap.parse_args()
    try:
        rec = run_case(args.arch, args.shape, args.multipod,
                       hlo_dir=(args.out + "/hlo") if args.save_hlo else None,
                       kv_quant=args.kv_quant)
    except Exception as e:  # noqa: BLE001 — record the failure for the table
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multipod else "16x16",
               "status": "fail", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    os.makedirs(args.out, exist_ok=True)
    tag = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}.json"
    with open(os.path.join(args.out, tag), "w") as f:
        json.dump(rec, f, indent=2)
    status = rec["status"]
    mem = rec.get("memory", {}).get("peak_bytes", 0) / 2**30
    print(f"[dryrun] {rec['arch']} x {rec['shape']} x {rec['mesh']}: {status}"
          + (f"  peak={mem:.2f} GiB/dev  dot_flops/dev={rec['cost']['dot_flops_per_device']:.3g}"
             if status == "ok" else "")
          + (f"  ({rec.get('note') or rec.get('error', '')})"
             if rec.get("note") or rec.get("error") else ""))
    if status == "fail":
        raise SystemExit(1)


if __name__ == "__main__":
    main()
