"""Opportunistic sharding hints.

``hint(x, *axes)`` applies ``with_sharding_constraint`` when a hint mesh is
active and the named axes divide the corresponding dims; it is a no-op on
CPU tests / single-device runs.  Model code can therefore express "this dim
wants to live on that axis" without hard-coupling to a mesh.

The mesh is registered explicitly (``set_hint_mesh`` / ``hint_mesh``
context manager) by the launcher before tracing — JAX's `with mesh:`
context does not expose the mesh to traced code in the Auto-sharding mode
this framework uses.
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH = None


def set_hint_mesh(mesh):
    global _MESH
    _MESH = mesh


@contextlib.contextmanager
def hint_mesh(mesh):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield
    finally:
        _MESH = prev


def hint(x, *axes):
    """axes: one entry per dim — an axis name, a tuple of axis names (joint
    sharding), or None.  Silently drops axes that are absent from the mesh,
    already used, or do not divide the dim."""
    mesh = _MESH
    if mesh is None:
        return x
    spec = []
    used = set()

    def usable(ax_tuple, dim):
        size = 1
        for a in ax_tuple:
            if a not in mesh.axis_names or a in used or mesh.shape[a] <= 1:
                return False
            size *= mesh.shape[a]
        return dim % size == 0

    for dim, ax in zip(x.shape, axes):
        if ax is None:
            spec.append(None)
            continue
        ax_t = ax if isinstance(ax, tuple) else (ax,)
        if usable(ax_t, dim):
            spec.append(ax if isinstance(ax, tuple) else ax)
            used.update(ax_t)
        elif not isinstance(ax, tuple) and usable((ax,), dim):
            spec.append(ax)
            used.add(ax)
        else:
            # tuple fallback: try the first axis alone
            if isinstance(ax, tuple) and usable((ax[0],), dim):
                spec.append(ax[0])
                used.add(ax[0])
            else:
                spec.append(None)
    if not any(a is not None for a in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
