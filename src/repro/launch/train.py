"""Training launcher.

Modes:
  pretrain    — full-backbone LM pretraining (substrate; tiny archs on CPU)
  dvi-online  — the paper's protocol: speculative generation with logging +
                online LoRA updates over a prompt stream
  dvi-batch   — teacher-forced DVI drafter updates over token batches
                (the `train_4k` dry-run workload, runnable for tiny archs)

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch vicuna-7b --tiny \\
      --mode dvi-online --prompts 200 --batch 8 --max-new 24
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint, save_lora
from repro.configs import get_config
from repro.core import online as online_mod
from repro.data import SyntheticTasks, TASK_CATEGORIES
from repro.models.model import build_model
from repro.optim import adamw_init
from repro.training import make_dvi_train_step, pretrain


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vicuna-7b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--mode", default="dvi-online",
                    choices=["pretrain", "dvi-online", "dvi-batch"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--prompts", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--loss-mode", default="full",
                    choices=["full", "kl", "pg", "ce"])
    ap.add_argument("--pretrain-steps", type=int, default=200,
                    help="backbone warmup before DVI modes")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=args.tiny).replace(dtype=args.dtype)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    tasks = SyntheticTasks(cfg.vocab_size, seed=args.seed)
    t0 = time.time()

    if args.mode == "pretrain" or args.pretrain_steps:
        n = args.steps if args.mode == "pretrain" else args.pretrain_steps
        params, losses = pretrain(
            model, params, tasks.stream(TASK_CATEGORIES, n, args.batch,
                                        args.seq, seed=args.seed + 1),
            lr=2e-3, log_every=max(n // 4, 1))
        print(f"[train] pretrain {n} steps: loss {losses[0]:.3f} -> "
              f"{losses[-1]:.3f} ({time.time()-t0:.1f}s)")
        if args.mode == "pretrain":
            if args.ckpt:
                save_checkpoint(args.ckpt, params)
            return

    state = online_mod.init_trainer(model, jax.random.PRNGKey(args.seed + 7))

    if args.mode == "dvi-online":
        n_batches = max(args.prompts // args.batch, 1)
        stream = tasks.stream(TASK_CATEGORIES, n_batches, args.batch,
                              args.seq // 2, seed=args.seed + 2)
        state, hist = online_mod.online_loop(
            model, params, stream, state, max_new=args.max_new,
            mode=args.loss_mode, lr=args.lr,
            log_every=max(n_batches // 10, 1))
        acc = np.array(hist["block_acc"])
        print(f"[train] dvi-online: block_acc {acc[:5].mean():.3f} -> "
              f"{acc[-5:].mean():.3f}; MAT {np.mean(hist['mat'][-5:]):.2f} "
              f"({time.time()-t0:.1f}s)")
    else:
        step_fn = make_dvi_train_step(model, lr=args.lr, mode=args.loss_mode)
        opt = adamw_init(state.dvi_params)
        baseline = jnp.float32(0.0)
        dvi_params = state.dvi_params
        for i, tokens in enumerate(tasks.stream(
                TASK_CATEGORIES, args.steps, args.batch, args.seq,
                seed=args.seed + 3)):
            dvi_params, opt, baseline, metrics = step_fn(
                params, dvi_params, opt, jnp.asarray(tokens), jnp.int32(i),
                baseline)
            if (i + 1) % max(args.steps // 10, 1) == 0:
                print(f"[train] dvi-batch step {i+1}: "
                      f"acc={float(metrics['acc_rate']):.3f} "
                      f"loss={float(metrics['loss']):.4f}")
        state.dvi_params = dvi_params

    if args.ckpt:
        save_lora(args.ckpt, state.dvi_params, int(state.step),
                  float(state.baseline))
        print(f"[train] saved LoRA checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
