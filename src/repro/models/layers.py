"""Shared layer primitives: RMSNorm, RoPE, GQA attention, GLU MLPs.

Conventions
-----------
* activations: (B, T, d); attention heads laid out (B, T, H, hd).
* norms and softmax run in float32 regardless of model dtype.
* KV caches are written eagerly at ``lengths + i``; speculative rollback is
  handled purely by length masking (full cache) or by a slack ring buffer
  (sliding-window cache) — see ``repro/models/transformer.py``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(x.dtype)


def head_rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Per-head qk RMSNorm (Qwen3): x (..., H, hd), w (hd,)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, hd); positions: (B, T) int32.  Half-split convention."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                            # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B, T, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(num_pos: int, dim: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings (num_pos, dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = jnp.arange(num_pos, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def attend(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
           scale: Optional[float] = None) -> jax.Array:
    """q (B,Tq,H,hd), k/v (B,Tk,KV,hd), mask (B,Tq,Tk) or (Tq,Tk) bool.

    GQA: H must be a multiple of KV; query heads are grouped onto kv heads.
    Returns (B, Tq, H, hd_v).
    """
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Tq, KV, G, hd)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg, k).astype(jnp.float32) * scale
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskh->btkgh", probs, v)
    return out.reshape(B, Tq, KV * G, v.shape[-1])


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """Declarative attention mask: causal [+ window] [+ bidirectional prefix]
    or fully bidirectional.  Used instead of materialized (Tq, Tk) masks so
    the flash path never builds a quadratic tensor."""
    window: int = 0
    prefix_len: int = 0
    bidirectional: bool = False

    def allowed(self, qpos, kpos):
        """qpos (..., Tq, 1), kpos (..., 1, Tk) -> bool."""
        if self.bidirectional:
            return jnp.ones(jnp.broadcast_shapes(qpos.shape, kpos.shape), bool)
        m = kpos <= qpos
        if self.window:
            m &= kpos > qpos - self.window
        if self.prefix_len:
            m |= (qpos < self.prefix_len) & (kpos < self.prefix_len)
        return m


# flash path kicks in above this many score elements (per example pair)
_FLASH_THRESHOLD = 1024 * 1024


def attend_full(q: jax.Array, k: jax.Array, v: jax.Array, spec: MaskSpec,
                q_chunk: int = 256, k_chunk: int = 1024) -> jax.Array:
    """Full-sequence self-attention with a declarative mask.

    Small T: materialize the mask and use `attend`.  Large T: blockwise
    online-softmax (flash) via lax.scan over (q-chunk, k-chunk) — memory
    O(T * k_chunk) instead of O(T^2), which is what makes the 32k prefill
    and 4k x 256-batch training shapes lowerable."""
    B, Tq, H, hd = q.shape
    Tk = k.shape[1]
    if Tq * Tk <= _FLASH_THRESHOLD:
        mask = causal_mask(Tq, Tk, window=spec.window, prefix_len=spec.prefix_len) \
            if not spec.bidirectional else jnp.ones((Tq, Tk), bool)
        return attend(q, k, v, mask)

    KV = k.shape[2]
    hdv = v.shape[-1]                       # may differ from hd (MLA)
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qc = min(q_chunk, Tq)
    kc = min(k_chunk, Tk)
    pad_q = (-Tq) % qc
    pad_k = (-Tk) % kc
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    # padded key slots masked off via kpos >= Tk
    real_k = Tk
    nq, nk = qp.shape[1] // qc, kp.shape[1] // kc
    from repro.launch.hints import hint
    qb = jnp.moveaxis(qp.reshape(B, nq, qc, KV, G, hd), 1, 0)   # (nq,B,qc,KV,G,hd)
    kb = jnp.moveaxis(kp.reshape(B, nk, kc, KV, hd), 1, 0)
    vb = jnp.moveaxis(vp.reshape(B, nk, kc, KV, hdv), 1, 0)
    # batch takes both axes when it divides (pure-FSDP training layout —
    # weights are gathered per layer, so heads stay unsharded); otherwise
    # batch on "data" and heads on "model": KV dim when it divides
    # (MHA/MLA), else query-head groups (GQA).  hint() dedups axes.
    qb = hint(qb, None, ("data", "model"), None, "model", "model", None)
    kb = hint(kb, None, ("data", "model"), None, "model", None)
    vb = hint(vb, None, ("data", "model"), None, "model", None)

    def q_step(_, qi_and_blk):
        qi, qblk = qi_and_blk                                   # (B,qc,KV,G,hd)
        qpos = qi * qc + jnp.arange(qc)

        def k_step(carry, kj_and_blk):
            m_run, l_run, acc = carry
            kj, kblk, vblk = kj_and_blk
            kpos = kj * kc + jnp.arange(kc)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qblk, kblk).astype(jnp.float32) * scale
            allowed = spec.allowed(qpos[:, None], kpos[None, :]) \
                & (kpos[None, :] < real_k)
            s = jnp.where(allowed[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(vblk.dtype), vblk).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qc, hdv), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        o = acc / jnp.maximum(l_f, 1e-30)[..., None]
        o = jnp.moveaxis(o, 3, 1).reshape(B, qc, KV * G, hdv)    # (B,qc,H,hdv)
        return None, o.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * qc, H, hdv)
    return out[:, :Tq]


def causal_mask(Tq: int, Tk: int, offset: int = 0, window: int = 0,
                prefix_len: int = 0) -> jax.Array:
    """(Tq, Tk) bool.  Query i sits at absolute position offset+i; key j at j.

    window > 0: sliding-window (local) attention.
    prefix_len > 0: bidirectional attention within keys/queries < prefix_len
    (prefix-LM, PaliGemma image prefix).
    """
    qpos = offset + jnp.arange(Tq)[:, None]
    kpos = jnp.arange(Tk)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    if prefix_len:
        m |= (qpos < prefix_len) & (kpos < prefix_len)
    return m


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp(p: dict, x: jax.Array, act: str, glu: bool) -> jax.Array:
    fn = jax.nn.silu if act == "silu" else (lambda u: jax.nn.gelu(u, approximate=True))
    h = x @ p["wi"]
    if glu:
        h = fn(h) * (x @ p["wg"])
    else:
        h = fn(h)
    return h @ p["wo_ff"]


def conv1d_causal(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal conv.  x (B,T,C), w (cw,C).  state (B,cw-1,C) holds
    the trailing inputs of the previous block.  Returns (y, new_state)."""
    cw = w.shape[0]
    B, T, C = x.shape
    if state is None:
        state = jnp.zeros((B, cw - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)             # (B, T+cw-1, C)
    y = jnp.zeros((B, T, C), jnp.float32)
    for i in range(cw):
        y = y + xp[:, i:i + T].astype(jnp.float32) * w[i].astype(jnp.float32)
    new_state = xp[:, T:]                                # last cw-1 inputs
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Parameter init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))
