"""DeepSeek-V3 Multi-head Latent Attention [arXiv:2412.19437].

The KV cache stores only the compressed latent ``c_kv`` (kv_lora_rank) plus
the shared RoPE key (qk_rope_head_dim) per token — a ~14x cache reduction
vs. MHA at 128 heads.  Decode uses the *absorbed* form (W_uk folded into the
query, W_uv applied after attention over latents) so the per-step cost is
O(S * (r_kv + d_rope)) per head instead of O(S * (d_nope + d_rope)) plus
decompression.  Prefill/train uses the naive decompressed form (better MXU
utilization at large T).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models.layers import (NEG_INF, apply_rope, attend_full, dense_init,
                                 rms_norm, split_keys)


def init_mla(key, n: int, d: int, H: int, m: MLAConfig, dtype) -> dict:
    ks = split_keys(key, 8)
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    return {
        "w_dq": dense_init(ks[0], (n, d, m.q_lora_rank), dtype),
        "q_ln": jnp.zeros((n, m.q_lora_rank), jnp.float32),
        "w_uq": dense_init(ks[1], (n, m.q_lora_rank, H * (dn + dr)), dtype),
        "w_dkv": dense_init(ks[2], (n, d, m.kv_lora_rank), dtype),
        "kv_ln": jnp.zeros((n, m.kv_lora_rank), jnp.float32),
        "w_kr": dense_init(ks[3], (n, d, dr), dtype),
        "w_uk": dense_init(ks[4], (n, m.kv_lora_rank, H * dn), dtype),
        "w_uv": dense_init(ks[5], (n, m.kv_lora_rank, H * dv), dtype),
        "w_o": dense_init(ks[6], (n, H * dv, d), dtype),
    }


def _queries(p, xn, H, m, positions, rope_theta):
    B, T = xn.shape[:2]
    dn, dr = m.qk_nope_head_dim, m.qk_rope_head_dim
    q = rms_norm(xn @ p["w_dq"], p["q_ln"]) @ p["w_uq"]
    q = q.reshape(B, T, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, rope_theta)
    return q_nope, q_rope


def _latents(p, xn, positions, rope_theta):
    ckv = rms_norm(xn @ p["w_dkv"], p["kv_ln"])                    # (B,T,r_kv)
    krope = apply_rope((xn @ p["w_kr"])[:, :, None, :], positions, rope_theta)[:, :, 0]
    return ckv, krope


def mla_full(p: dict, xn: jax.Array, H: int, m: MLAConfig, positions, spec,
             rope_theta: float):
    """Decompressed attention over the full sequence (flash path for large T).

    The shared RoPE key folds into per-head keys so standard attention with
    head_dim = dn + dr computes q_nope.k_nope + q_rope.k_rope exactly.
    xn: pre-normed (B,T,d); spec: MaskSpec.
    Returns (attn_out (B,T,d), cache_contrib {ckv, krope})."""
    B, T = xn.shape[:2]
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q_nope, q_rope = _queries(p, xn, H, m, positions, rope_theta)
    ckv, krope = _latents(p, xn, positions, rope_theta)
    k_nope = (ckv @ p["w_uk"]).reshape(B, T, H, dn)
    v = (ckv @ p["w_uv"]).reshape(B, T, H, dv)
    from repro.launch.hints import hint
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)         # (B,T,H,dn+dr)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :], (B, T, H, dr))], axis=-1)
    q_cat = hint(q_cat, "data", None, "model", None)
    k_cat = hint(k_cat, "data", None, "model", None)
    v = hint(v, "data", None, "model", None)
    out = attend_full(q_cat, k_cat, v, spec).reshape(B, T, H * dv)
    return out @ p["w_o"], {"ckv": ckv, "krope": krope}


def mla_step(p: dict, xn: jax.Array, cache_ckv, cache_krope, lengths,
             H: int, m: MLAConfig, positions, rope_theta: float):
    """Absorbed-form block decode.  cache_ckv (B,S,r_kv), cache_krope (B,S,dr).

    Writes the block's latents eagerly at lengths..lengths+T-1 (rollback via
    length masking).  Returns (attn_out, new_ckv, new_krope)."""
    B, T = xn.shape[:2]
    S = cache_ckv.shape[1]
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    r_kv = m.kv_lora_rank
    q_nope, q_rope = _queries(p, xn, H, m, positions, rope_theta)
    ckv, krope = _latents(p, xn, positions, rope_theta)

    from repro.models.transformer import spread_write
    new_ckv = spread_write(cache_ckv, ckv, lengths, wrap=False)
    new_krope = spread_write(cache_krope, krope, lengths, wrap=False)

    # absorb W_uk into q:  q_eff[b,t,h,:] = q_nope · W_uk_h  -> (B,T,H,r_kv)
    w_uk = p["w_uk"].reshape(r_kv, H, dn)
    q_eff = jnp.einsum("bthd,rhd->bthr", q_nope, w_uk)
    scale = 1.0 / math.sqrt(dn + dr)
    scores = (jnp.einsum("bthr,bsr->bhts", q_eff, new_ckv)
              + jnp.einsum("bthd,bsd->bhts", q_rope, new_krope)).astype(jnp.float32) * scale
    qpos = lengths[:, None] + jnp.arange(T)[None, :]               # (B,T)
    mask = jnp.arange(S)[None, None, :] <= qpos[:, :, None]        # (B,T,S)
    scores = jnp.where(mask[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(xn.dtype)
    o_lat = jnp.einsum("bhts,bsr->bthr", probs, new_ckv)           # (B,T,H,r_kv)
    w_uv = p["w_uv"].reshape(r_kv, H, dv)
    out = jnp.einsum("bthr,rhd->bthd", o_lat, w_uv).reshape(B, T, H * dv)
    return out @ p["w_o"], new_ckv, new_krope
