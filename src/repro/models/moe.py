"""Mixture-of-Experts FFN with grouped capacity dispatch (GShard-style).

TPU-native formulation: tokens are split into G groups (G = the data-
parallel axis size when a hint mesh is active, so each group is one device's
shard), each group scatters its tokens into a per-group ``(E, C_local, d)``
dispatch buffer — a *local* scatter GSPMD executes without cross-device
regather — and the expert einsum contracts group-sharded buffers against
expert-sharded weights, which lowers to the canonical expert-parallel
all-to-all.  Tokens overflowing per-group expert capacity are dropped
(capacity-factor routing); decode uses dropless capacity C = N_local.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import dense_init, split_keys


def init_moe(key, n: int, d: int, mo: MoEConfig, glu: bool, dtype) -> dict:
    ks = split_keys(key, 8)
    fe, E = mo.d_ff_expert, mo.num_experts
    p = {
        "router": dense_init(ks[0], (n, d, E), jnp.float32),
        "we_gate": dense_init(ks[1], (n, E, d, fe), dtype),
        "we_down": dense_init(ks[2], (n, E, fe, d), dtype),
    }
    if glu:
        p["we_up"] = dense_init(ks[3], (n, E, d, fe), dtype)
    if mo.num_shared_experts:
        fs = (mo.d_ff_shared or fe) * mo.num_shared_experts
        p["ws_gate"] = dense_init(ks[4], (n, d, fs), dtype)
        p["ws_down"] = dense_init(ks[5], (n, fs, d), dtype)
        if glu:
            p["ws_up"] = dense_init(ks[6], (n, d, fs), dtype)
    return p


def _expert_ranks(flat_e: jax.Array, E: int) -> jax.Array:
    """rank of each row within its expert id (0-based).

    Small N: one-hot cumsum (cheap, no collectives).  Large N: sort-based
    (megablox-style routing) — O(N log N) with O(N) memory instead of the
    O(N*E) one-hot tensor."""
    Nk = flat_e.shape[0]
    if Nk * E <= 1 << 22:
        oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        ranks = jnp.cumsum(oh, axis=0) - oh
        return jnp.take_along_axis(ranks, flat_e[:, None], axis=1)[:, 0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    ar = jnp.arange(Nk, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool),
                                sorted_e[1:] != sorted_e[:-1]])
    seg_start = jax.lax.associative_scan(jnp.maximum,
                                         jnp.where(is_start, ar, 0))
    rank_sorted = ar - seg_start
    return jnp.zeros((Nk,), jnp.int32).at[order].set(rank_sorted)


def _num_groups(N: int) -> int:
    from repro.launch import hints
    mesh = hints._MESH
    if mesh is None:
        return 1
    g = 1
    for ax in ("data",):
        if ax in mesh.axis_names and N % (g * mesh.shape[ax]) == 0:
            g *= mesh.shape[ax]
    return g


def moe_ffn(p: dict, x: jax.Array, mo: MoEConfig, act: str, glu: bool,
            dropless: bool = False):
    """x: (B, T, d).  Returns (y, aux_loss).

    dropless=True sets per-group capacity C = N_local (a single expert can
    receive at most one choice per token), guaranteeing no token is ever
    dropped.  Decode steps use this — it makes speculative verification on
    MoE architectures *deterministic* and hence lossless."""
    B, T, d = x.shape
    N, E, k = B * T, mo.num_experts, mo.top_k
    fn = jax.nn.silu if act == "silu" else (lambda u: jax.nn.gelu(u, approximate=True))
    from repro.launch.hints import hint

    g = _num_groups(N)
    n_loc = N // g
    xg = hint(x.reshape(g, n_loc, d), "data", None, None)

    logits = (xg.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (g,n,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                                   # (g,n,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = idx.reshape(g, n_loc * k)
    slot = jax.vmap(lambda fe: _expert_ranks(fe, E))(flat_e)              # (g,n*k)

    C = n_loc if dropless else max(8, int(math.ceil(n_loc * k / E
                                                    * mo.capacity_factor)))
    keep = slot < C
    slot_c = jnp.where(keep, slot, C)                                     # C => drop

    upd = hint(jnp.repeat(xg, k, axis=1), "data", None, "model")          # (g,n*k,d)

    # Gather-based dispatch: GSPMD partitions gathers with pass-through
    # batch dims cleanly, whereas data-dependent scatters of the token
    # payload fall back to full replication (10-30x per-device memory).
    # Only the tiny int32 slot->row index map is built by scatter.
    def index_map(fe, sl):
        m = jnp.full((E, C + 1), -1, jnp.int32).at[fe, sl].set(
            jnp.arange(fe.shape[0], dtype=jnp.int32), mode="drop")
        return m[:, :C]
    idx_map = jax.vmap(index_map)(flat_e, slot_c)                         # (g,E,C)
    gidx = jnp.maximum(idx_map, 0).reshape(g, E * C)
    buf = jnp.take_along_axis(upd, gidx[..., None], axis=1)               # (g,E*C,d)
    buf = jnp.where((idx_map >= 0).reshape(g, E * C)[..., None],
                    buf, jnp.zeros((), x.dtype)).reshape(g, E, C, d)
    buf = hint(buf, "data", None, None, "model")                          # local
    # expert-parallel re-layout: experts move onto "model" (all-to-all
    # within model groups only; the data axis never transposes)
    buf = hint(buf, "data", "model", None, None)

    h = jnp.einsum("gecd,edf->gecf", buf, p["we_gate"])
    if glu:
        h = fn(h) * jnp.einsum("gecd,edf->gecf", buf, p["we_up"])
    else:
        h = fn(h)
    h = hint(h, "data", "model", None, None)
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["we_down"])               # (g,E,C,d)
    out_buf = hint(out_buf, "data", "model", None, None)

    flat_slot = flat_e * C + jnp.minimum(slot_c, C - 1)                   # (g,n*k)
    rows = jnp.take_along_axis(
        hint(out_buf.reshape(g, E * C, d), "data", None, None),
        flat_slot[..., None], axis=1)                                     # (g,n*k,d)
    rows = rows * (keep[..., None]
                   * gate.reshape(g, n_loc * k)[..., None]).astype(rows.dtype)
    y = rows.reshape(g, n_loc, k, d).sum(axis=2)

    if mo.num_shared_experts:
        hs = xg @ p["ws_gate"]
        hs = fn(hs) * (xg @ p["ws_up"]) if glu else fn(hs)
        y = y + hs @ p["ws_down"]

    # load-balance auxiliary loss (Switch-style): E * <f_e><p_e>
    frac_dispatch = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32),
                             axis=(0, 1, 2))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_dispatch * frac_probs) * mo.router_aux_weight
    return y.reshape(B, T, d), aux
