"""Griffin / RecurrentGemma recurrent block [arXiv:2402.19427].

Block: x -> RMSNorm -> two branches:
  (a) gate branch: GeLU(W_gate x)
  (b) recurrent branch: causal conv1d(W_x x) -> RG-LRU
merged multiplicatively, then output projection.  RG-LRU recurrence:
  r_t = sigmoid(W_a u_t),  i_t = sigmoid(W_i u_t)
  log a_t = -c * softplus(Lambda) * r_t           (c = 8)
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Full-sequence path uses ``jax.lax.associative_scan`` (parallel over T, which
is how the deep path block-verifies drafted tokens); decode path scans over
the block and returns per-step states for speculative commit-select.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RGLRUConfig
from repro.models.layers import conv1d_causal, dense_init, rms_norm, split_keys

_C = 8.0


def init_rglru(key, n: int, d: int, r: RGLRUConfig, dtype) -> dict:
    w = r.lru_width or d
    ks = split_keys(key, 6)
    return {
        "ln1": jnp.zeros((n, d), jnp.float32),
        "w_gate": dense_init(ks[0], (n, d, w), dtype),
        "w_x": dense_init(ks[1], (n, d, w), dtype),
        "conv_w": dense_init(ks[2], (n, r.d_conv, w), jnp.float32, scale=0.5),
        "w_a": dense_init(ks[3], (n, w, w), dtype),
        "w_i": dense_init(ks[4], (n, w, w), dtype),
        # Lambda init so that a^c in [0.9, 0.999] at r=1 (Griffin appendix)
        "lam": jnp.tile(jnp.linspace(0.5, 4.0, w, dtype=jnp.float32), (n, 1)),
        "w_o": dense_init(ks[5], (n, w, d), dtype),
    }


def _gates(p, u):
    rf = jax.nn.sigmoid((u @ p["w_a"]).astype(jnp.float32))
    it = jax.nn.sigmoid((u @ p["w_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"])[None, None, :] * rf
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * it * u.astype(jnp.float32)
    return a, b


def rglru_forward_full(p: dict, x: jax.Array, r: RGLRUConfig, norm_eps: float,
                       conv_state=None, h0=None):
    """x (B,T,d).  Returns (y, cache_contrib {conv, state})."""
    xn = rms_norm(x, p["ln1"], norm_eps)
    gate = jax.nn.gelu((xn @ p["w_gate"]).astype(jnp.float32), approximate=True)
    u, conv_state = conv1d_causal(xn @ p["w_x"], p["conv_w"], conv_state)
    a, b = _gates(p, u)                                    # (B,T,w) f32
    if h0 is not None:
        # fold initial state into the first element: h_1 = a_1 h0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    A, Bv = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = Bv                                                 # (B,T,w)
    y = (h * gate).astype(x.dtype) @ p["w_o"]
    return x + y, {"conv": conv_state, "state": h[:, -1]}


def rglru_step(p: dict, x: jax.Array, cache: dict, r: RGLRUConfig,
               norm_eps: float):
    """Block decode; returns (y, candidates {conv (B,T,cw-1,w), state (B,T,w)})."""
    B_, T, d = x.shape
    xn = rms_norm(x, p["ln1"], norm_eps)
    gate = jax.nn.gelu((xn @ p["w_gate"]).astype(jnp.float32), approximate=True)
    ux = xn @ p["w_x"]

    def step_fn(carry, u_t):
        conv_st, h = carry
        win = jnp.concatenate([conv_st, u_t[:, None]], axis=1)  # (B,cw,w)
        u = jnp.sum(win.astype(jnp.float32) * p["conv_w"][None], axis=1)
        u = u.astype(x.dtype)[:, None]                          # (B,1,w)
        a, b = _gates(p, u)
        h = a[:, 0] * h + b[:, 0]
        new_conv = win[:, 1:]
        return (new_conv, h), (h, new_conv)

    (_, _), (hs, convs) = jax.lax.scan(
        step_fn, (cache["conv"], cache["state"].astype(jnp.float32)),
        jnp.moveaxis(ux, 1, 0))
    h = jnp.moveaxis(hs, 0, 1)                             # (B,T,w)
    y = (h * gate).astype(x.dtype) @ p["w_o"]
    cand = {"conv": jnp.moveaxis(convs, 0, 1), "state": h}
    return x + y, cand


def init_rglru_cache(n: int, B: int, d: int, r: RGLRUConfig, dtype):
    w = r.lru_width or d
    return {"conv": jnp.zeros((n, B, r.d_conv - 1, w), dtype),
            "state": jnp.zeros((n, B, w), jnp.float32)}
