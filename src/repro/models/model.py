"""Public model API: one composable interface over all architecture families.

    model = build_model(cfg)
    params = model.init(rng)
    h      = model.hidden(params, tokens, aux, lo=0, hi=L)     # full-seq
    logits = model.logits(params, h)                            # frozen head
    cache  = model.init_cache(B, max_len)
    logits, cache = model.prefill(params, tokens, aux)
    h, cache, cands, aux = model.step(params, x_blk, cache, lo, hi)

DVI composes these: the draft path is ``hidden/step`` with ``hi = k`` plus
the LoRA draft head (repro.core.lora); the target path is ``lo = k`` →
``logits``.  ``aux_inputs`` carries the stubbed modality frontends
(audio frame embeddings, VLM patch embeddings) per the assignment carve-out.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.models.layers import dense_init, rms_norm, sinusoidal_positions, split_keys


@dataclass
class Model:
    cfg: ModelConfig

    # ---------------- init ----------------
    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = cfg.jnp_dtype
        ks = split_keys(key, 8 + 2 * len(tfm.model_segments(cfg)))
        params = {
            "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype, scale=0.02),
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "segments": {},
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dtype)
        for i, seg in enumerate(tfm.model_segments(cfg)):
            params["segments"][seg.name] = tfm.init_segment(ks[4 + i], cfg, seg, dtype)
        if cfg.encoder is not None:
            params["encoder"] = self._init_encoder(ks[2], dtype)
        if cfg.vision is not None:
            params["vision_proj"] = dense_init(ks[3], (cfg.vision.d_embed, cfg.d_model), dtype)
        if cfg.mtp_depth:
            # DeepSeek-V3 MTP: one extra transformer layer + projection that
            # predicts token t+2 from [h_t ; emb(t+1)]
            mtp_seg = tfm.Segment(0, "attn", "dense", 0, 1, cfg.moe.d_ff_dense
                                  if cfg.moe else cfg.d_ff)
            params["mtp"] = {
                "proj": dense_init(ks[5], (2 * cfg.d_model, cfg.d_model), dtype),
                "norm": jnp.zeros((cfg.d_model,), jnp.float32),
                "layer": tfm.init_segment(ks[6], cfg, mtp_seg, dtype),
            }
        return params

    def _init_encoder(self, key, dtype):
        cfg = self.cfg
        e = cfg.encoder
        d_enc = e.d_model or cfg.d_model
        ks = split_keys(key, e.num_layers + 2)
        seg = tfm.Segment(0, "attn", "dense", 0, e.num_layers, cfg.d_ff)
        return {
            "in_proj": dense_init(ks[0], (d_enc, cfg.d_model), dtype),
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "segments": {"s0": tfm.init_segment(ks[1], cfg, seg, dtype)},
        }

    # ---------------- embeddings ----------------
    def embed(self, params, tokens, aux_inputs: Optional[dict] = None,
              offset: int = 0):
        """tokens (B, T) -> x (B, T', d).  For VLM, patch embeddings are
        prepended (T' = n_patches + T); for audio, sinusoidal positions are
        added (the decoder has no RoPE)."""
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.arch_type in ("vlm",) or cfg.rglru is not None:
            x = x * math.sqrt(cfg.d_model)          # gemma-style embed scaling
        if cfg.vision is not None:
            patches = aux_inputs["patch_embeds"].astype(x.dtype)  # (B,P,d_embed)
            px = patches @ params["vision_proj"]
            x = jnp.concatenate([px, x], axis=1)
        if cfg.arch_type == "audio":
            T = x.shape[1]
            pos = sinusoidal_positions(offset + T, cfg.d_model)[offset:]
            x = x + pos[None].astype(x.dtype)
        return x

    def embed_block(self, params, tokens, lengths=None):
        """Decode-block embedding: no modality prefix (that lives in the
        cache after prefill).  For audio (absolute sinusoidal positions),
        per-sequence offsets come from `lengths` (B,)."""
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.arch_type in ("vlm",) or cfg.rglru is not None:
            x = x * math.sqrt(cfg.d_model)
        if cfg.arch_type == "audio":
            B, T = tokens.shape
            max_pos = 1 << 16
            table = sinusoidal_positions(max_pos, cfg.d_model)
            pos = lengths[:, None] + jnp.arange(T)[None, :]
            x = x + table[jnp.minimum(pos, max_pos - 1)].astype(x.dtype)
        return x

    def encode(self, params, aux_inputs):
        """Whisper encoder over stubbed frame embeddings (B, F, d_enc)."""
        cfg = self.cfg
        frames = aux_inputs["frame_embeds"]
        x = frames.astype(cfg.jnp_dtype) @ params["encoder"]["in_proj"]
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model)[None].astype(x.dtype)
        seg = tfm.Segment(0, "attn", "dense", 0, cfg.encoder.num_layers, cfg.d_ff)
        x, _, _ = tfm.run_segment_full(params["encoder"]["segments"]["s0"], x,
                                       cfg, seg, positions=jnp.zeros(
                                           (x.shape[0], x.shape[1]), jnp.int32),
                                       prefix_len=0, enc_out=None, collect=False)
        return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)

    # ---------------- full-sequence ----------------
    def hidden(self, params, x, lo: int = 0, hi: Optional[int] = None,
               positions=None, prefix_len: int = 0, enc_out=None,
               collect: bool = False, remat: bool = False):
        hi = self.cfg.num_layers if hi is None else hi
        return tfm.forward_full(params["segments"], x, self.cfg, lo, hi,
                                positions, prefix_len, enc_out, collect, remat)

    def logits(self, params, h):
        """Frozen verifier head (final norm + unembed)."""
        hn = rms_norm(h, params["final_norm"], self.cfg.norm_eps)
        W = self.head_matrix(params)
        return hn @ W

    def head_matrix(self, params):
        return (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])

    def forward_train(self, params, tokens, aux_inputs=None, remat: bool = False):
        """Full-model LM forward: (B,T) -> (logits, aux_loss)."""
        enc = self.encode(params, aux_inputs) if self.cfg.encoder is not None else None
        x = self.embed(params, tokens, aux_inputs)
        h, _, aux = self.hidden(params, x, enc_out=enc, remat=remat,
                                prefix_len=self._prefix_len(aux_inputs))
        return self.logits(params, h), aux

    def _prefix_len(self, aux_inputs):
        if self.cfg.vision is not None:
            return self.cfg.vision.num_patches
        return 0

    # ---------------- cache / decode ----------------
    def init_cache(self, B: int, max_len: int):
        return tfm.init_cache(self.cfg, B, max_len)

    def init_paged_cache(self, B: int, num_pages: int, page_size: int,
                         max_pages_per_slot: int):
        """Pooled paged decode cache: full-attention KV in `num_pages`
        shared pages addressed via a per-lane block table (see
        repro.serving.kv_pool for layout and rollback rules)."""
        return tfm.init_paged_cache(self.cfg, B, num_pages, page_size,
                                    max_pages_per_slot)

    def prefill(self, params, tokens, aux_inputs=None, cache=None,
                max_len: Optional[int] = None):
        """Process the prompt; build a decode cache.  Returns (h, cache, enc)."""
        cfg = self.cfg
        enc = self.encode(params, aux_inputs) if cfg.encoder is not None else None
        x = self.embed(params, tokens, aux_inputs)
        T = x.shape[1]
        if cache is None:
            cache = self.init_cache(x.shape[0], max_len or (T + 512))
        h, contribs, _ = self.hidden(params, x, enc_out=enc, collect=True,
                                     prefix_len=self._prefix_len(aux_inputs))
        cache = tfm.fill_cache_from_full(cfg, cache, contribs, T)
        return h, cache, enc

    def prefill_chunk(self, params, tokens, cache, take=None):
        """Resume a chunked prefill: process `tokens` (B, T) at positions
        ``cache["lengths"] .. +T-1`` against a partially-built cache and
        commit ``take`` (B,) of them per lane (default: all T).  Runs the
        block-decode path over the FULL stack, so it works against both
        contiguous and paged layouts and carries stateful-mixer conv/state
        exactly — a cache built by ``prefill(first chunk)`` +
        ``prefill_chunk(rest)`` decodes bit-identically to one-shot
        ``prefill`` (tested in tests/test_chunked_prefill.py).

        ``take < T`` supports ragged last chunks in a fixed-shape batched
        call: positions past ``take`` are padding whose eager cache writes
        are rolled back by length masking, exactly like rejected
        speculative tokens.  ``take = 0`` leaves a lane untouched (riding
        lanes in a batched chunk step).  Returns (h, cache)."""
        B, T = tokens.shape
        take = (jnp.full((B,), T, jnp.int32) if take is None
                else take.astype(jnp.int32))
        x = self.embed_block(params, tokens, cache["lengths"])
        h, cache2, cands, _ = self.step(params, x, cache)
        return h, tfm.commit_cache(self.cfg, cache2, cands, take)

    def step(self, params, x, cache, lo: int = 0, hi: Optional[int] = None):
        """Block-decode layers [lo,hi) on embedded block x (B,T,d)."""
        hi = self.cfg.num_layers if hi is None else hi
        return tfm.forward_step(params["segments"], x, self.cfg, cache, lo, hi)

    def commit(self, cache, cands, accept):
        return tfm.commit_cache(self.cfg, cache, cands, accept)

    # ---------------- MTP auxiliary head (DeepSeek-V3) ----------------
    def mtp_logits(self, params, h, tokens_next):
        """Predict token t+2 from [h_t ; emb(t+1)] through one extra layer."""
        cfg = self.cfg
        emb = params["embed"][tokens_next]
        z = jnp.concatenate([rms_norm(h, params["mtp"]["norm"], cfg.norm_eps),
                             emb], axis=-1) @ params["mtp"]["proj"]
        seg = tfm.Segment(0, "attn", "dense", 0, 1,
                          cfg.moe.d_ff_dense if cfg.moe else cfg.d_ff)
        T = z.shape[1]
        pos = jnp.broadcast_to(jnp.arange(T)[None, :], z.shape[:2])
        z, _, _ = tfm.run_segment_full(params["mtp"]["layer"], z, cfg, seg,
                                       pos, 0, None, collect=False)
        return self.logits(params, z)


def build_model(cfg: ModelConfig) -> Model:
    cfg.validate()
    return Model(cfg)


def input_token_specs(cfg: ModelConfig, B: int, T: int) -> dict:
    """jax.ShapeDtypeStruct stand-ins for every model input (dry-run)."""
    specs = {"tokens": jax.ShapeDtypeStruct((B, T), jnp.int32)}
    if cfg.vision is not None:
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision.num_patches, cfg.vision.d_embed), jnp.float32)
    if cfg.encoder is not None:
        e = cfg.encoder
        specs["frame_embeds"] = jax.ShapeDtypeStruct(
            (B, e.num_frames, e.d_model or cfg.d_model), jnp.float32)
    return specs
