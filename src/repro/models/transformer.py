"""Segmented decoder stack with a unified speculative-decoding cache.

The layer list is grouped into *segments* — maximal runs of layers with the
same (mixer kind, ffn kind) — and each segment's parameters are stacked on a
leading layer axis and executed with ``jax.lax.scan`` (keeps HLO size O(#
segments), not O(#layers): llama3-405b lowers as a single 126-deep scan).

Crucially for DVI, segment boundaries are also cut at ``cfg.dvi.split_layer``
so the *draft path* (layers [0, k)) and *target path* ([k, L)) are separate
segment runs over one shared parameter tree.

Two execution modes:

* ``forward_full`` — whole sequence, no cache reads (train / prefill).
  Optionally returns per-layer cache contributions so prefill can build the
  decode cache.
* ``forward_step`` — a block of T tokens (T = k_spec + 1 during speculation,
  1 for plain AR) against the cache.  Attention caches are written eagerly
  (rollback = length masking; sliding-window caches use a slack ring so
  speculative writes never clobber live slots).  Stateful mixers (SSD,
  RG-LRU) return per-step candidate states; ``commit_cache`` selects the
  state at the accepted length.

Two cache layouts share these entry points:

* **contiguous** (``init_cache``) — each lane reserves a worst-case
  ``(B, max_len)`` KV region; simple, but one long request strands memory.
* **paged** (``init_paged_cache``) — full-attention KV lives in a shared
  page pool ``(n, P, page_size, KV, hd)`` addressed through a per-lane
  block table ``cache["tbl"]`` (see ``repro.serving.kv_pool`` for the
  layout and rollback rules); ring/SSD/RG-LRU segments keep their
  per-slot constant-size state.  ``insert_slot`` becomes a block-table
  scatter and ``reset_slot`` just unmaps the lane's row — physical pages
  are recycled host-side by the serving engine's ``KVPool``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (MaskSpec, NEG_INF, apply_rope, attend,
                                 attend_full, causal_mask, dense_init,
                                 head_rms_norm, mlp, rms_norm, split_keys)

# Extra ring slots so speculative writes never evict live KV.  128 (not the
# minimal k_spec+1) keeps ring capacities mesh-divisible: W + 128 stays a
# multiple of 256 for the production windows (2048, 8192), so the cache's
# sequence dim shards cleanly over a 16-way mesh axis.
RING_SLACK = 128


@dataclass(frozen=True)
class Segment:
    idx: int
    kind: str          # attn | local | ssm | rglru
    ffn: str           # dense | moe | none
    start: int
    n: int
    d_ff: int
    cross: bool = False

    @property
    def name(self) -> str:
        return f"s{self.idx}"


def layer_kinds(cfg: ModelConfig):
    pat = cfg.layer_pattern
    kinds = []
    for layer in range(cfg.num_layers):
        kind = pat[layer % len(pat)]
        if cfg.ssm is not None:
            ffn = "none"
        elif cfg.moe is not None and layer >= cfg.moe.first_dense_layers:
            ffn = "moe"
        else:
            ffn = "dense"
        kinds.append((kind, ffn))
    return kinds


def build_segments(cfg: ModelConfig, boundaries=()):
    """Group layers into stacked-scan segments; force cuts at `boundaries`."""
    kinds = layer_kinds(cfg)
    cuts = set(boundaries) | {0, cfg.num_layers}
    segs, idx = [], 0
    start = 0
    for layer in range(1, cfg.num_layers + 1):
        if (layer in cuts or layer == cfg.num_layers
                or kinds[layer] != kinds[start]):
            kind, ffn = kinds[start]
            if ffn == "dense" and cfg.moe is not None and cfg.moe.first_dense_layers:
                d_ff = cfg.moe.d_ff_dense or cfg.d_ff
            else:
                d_ff = cfg.d_ff
            segs.append(Segment(idx, kind, ffn, start, layer - start, d_ff,
                                cross=(cfg.arch_type == "audio")))
            idx += 1
            start = layer
    return segs


def model_segments(cfg: ModelConfig):
    return build_segments(cfg, boundaries=(cfg.dvi.split_layer,))


def segments_in_range(cfg: ModelConfig, lo: int, hi: int):
    return [s for s in model_segments(cfg) if s.start >= lo and s.start + s.n <= hi]


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_segment(key, cfg: ModelConfig, seg: Segment, dtype) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    n = seg.n
    ks = split_keys(key, 20)
    if seg.kind == "ssm":
        return ssm_mod.init_ssm(ks[0], n, d, cfg.ssm, dtype)
    p = {"ln1": jnp.zeros((n, d), jnp.float32),
         "ln2": jnp.zeros((n, d), jnp.float32)}
    if seg.kind == "rglru":
        p.update(rglru_mod.init_rglru(ks[0], n, d, cfg.rglru, dtype))
    elif cfg.mla is not None:
        p.update(mla_mod.init_mla(ks[0], n, d, H, cfg.mla, dtype))
    else:
        p["wq"] = dense_init(ks[1], (n, d, H * hd), dtype)
        p["wk"] = dense_init(ks[2], (n, d, KV * hd), dtype)
        p["wv"] = dense_init(ks[3], (n, d, KV * hd), dtype)
        p["wo"] = dense_init(ks[4], (n, H * hd, d), dtype)
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((n, H * hd), dtype)
            p["bk"] = jnp.zeros((n, KV * hd), dtype)
            p["bv"] = jnp.zeros((n, KV * hd), dtype)
        if cfg.qk_norm:
            p["qn"] = jnp.zeros((n, hd), jnp.float32)
            p["kn"] = jnp.zeros((n, hd), jnp.float32)
    if seg.cross and seg.kind in ("attn", "local"):
        p["ln_x"] = jnp.zeros((n, d), jnp.float32)
        p["wq_x"] = dense_init(ks[5], (n, d, H * hd), dtype)
        p["wk_x"] = dense_init(ks[6], (n, d, H * hd), dtype)
        p["wv_x"] = dense_init(ks[7], (n, d, H * hd), dtype)
        p["wo_x"] = dense_init(ks[8], (n, H * hd, d), dtype)
    # FFN
    if seg.ffn == "dense":
        f = seg.d_ff
        p["wi"] = dense_init(ks[10], (n, d, f), dtype)
        if cfg.glu:
            p["wg"] = dense_init(ks[11], (n, d, f), dtype)
        p["wo_ff"] = dense_init(ks[12], (n, f, d), dtype)
    elif seg.ffn == "moe":
        p["moe"] = moe_mod.init_moe(ks[13], n, d, cfg.moe, cfg.glu, dtype)
    return p


# ---------------------------------------------------------------------------
# Single-layer bodies
# ---------------------------------------------------------------------------

def _qkv(p, xn, cfg):
    B, T = xn.shape[:2]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = xn @ p["wq"]
    k = xn @ p["wk"]
    v = xn @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, H, hd)
    k = k.reshape(B, T, KV, hd)
    v = v.reshape(B, T, KV, hd)
    if cfg.qk_norm:
        q = head_rms_norm(q, p["qn"], cfg.norm_eps)
        k = head_rms_norm(k, p["kn"], cfg.norm_eps)
    return q, k, v


def _ffn(p, x, cfg, seg_ffn, aux, dropless=False):
    from repro.launch.hints import hint
    xn = rms_norm(x, p["ln2"], cfg.norm_eps)
    if dropless:
        # decode path: keep activations d-sharded over "data" so matmuls
        # against (d/data, f/model) weights run as weight-STATIONARY
        # partial sums + tiny activation all-reduces, instead of
        # all-gathering 2D-sharded weights every layer (51 GiB/step for
        # llama3-405b decode — see EXPERIMENTS.md §Perf H1)
        xn = hint(xn, None, None, "data")
    if seg_ffn == "moe":
        y, a = moe_mod.moe_ffn(p["moe"], xn, cfg.moe, cfg.act, cfg.glu, dropless)
        aux = aux + a
    else:
        # (H2 in EXPERIMENTS.md tried a batch-reduce-scatter GLU flow here;
        # it REGRESSED wire 7.1->8.1 GiB — GSPMD lowered the batch reshard
        # as all-gather+slice — so the plain flow stands.)
        y = mlp(p, xn, cfg.act, cfg.glu)
    return x + y, aux


def _cross_attn(p, x, cross_k, cross_v, cfg):
    """cross_k/v: (B, F, H, hd) — precomputed per layer from encoder output."""
    B, T = x.shape[:2]
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    xn = rms_norm(x, p["ln_x"], cfg.norm_eps)
    q = (xn @ p["wq_x"]).reshape(B, T, H, hd)
    out = attend_full(q, cross_k, cross_v, MaskSpec(bidirectional=True))
    return x + out.reshape(B, T, H * hd) @ p["wo_x"]


def attn_layer_full(p, x, cfg: ModelConfig, seg: Segment, positions, spec,
                    enc_out, aux, use_rope=True, collect=True):
    """Full-sequence attention layer.  Returns (x, cache_contrib, aux)."""
    from repro.launch.hints import hint
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    contrib = {}
    if cfg.mla is not None:
        out, lat = mla_mod.mla_full(p, xn, cfg.num_heads, cfg.mla, positions,
                                    spec, cfg.rope_theta)
        x = x + out
        if collect:
            # prefill cache contributions live sequence-sharded (they become
            # the decode cache; replicated they are 16x per-device memory)
            contrib = {"ckv": hint(lat["ckv"], "data", "model", None),
                       "krope": hint(lat["krope"], "data", "model", None)}
    else:
        q, k, v = _qkv(p, xn, cfg)
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        out = attend_full(q, k, v, spec)
        B, T = x.shape[:2]
        x = x + out.reshape(B, T, -1) @ p["wo"]
        if collect:
            contrib = {"k": hint(k, "data", "model", None, None),
                       "v": hint(v, "data", "model", None, None)}
    if seg.cross:
        B, F = enc_out.shape[:2]
        H, hd = cfg.num_heads, cfg.resolved_head_dim
        ck = (enc_out @ p["wk_x"]).reshape(B, F, H, hd)
        cv = (enc_out @ p["wv_x"]).reshape(B, F, H, hd)
        x = _cross_attn(p, x, ck, cv, cfg)
        if collect:
            contrib.update({"xk": ck, "xv": cv})
    x, aux = _ffn(p, x, cfg, seg.ffn, aux)
    return x, contrib, aux


def kv_quantize(x):
    """(..., KV, hd) -> (int8 values, f32 per-(slot, kv-head) scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def kv_dequantize(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def spread_write(cache, blk, lengths, wrap: bool = True):
    """Write blk (B,T,...) into cache (B,C,...) at slots lengths + i via an
    elementwise select (sharding-preserving).  ``wrap=True`` (ring caches):
    slots are (lengths + i) mod C.  ``wrap=False`` (full / MLA caches, where
    slot index == absolute position): out-of-capacity writes are DROPPED —
    a position past C can only ever be an eager speculative / chunk-padding
    write that rollback would discard anyway, and wrapping it would clobber
    committed slots near 0.  Both clauses are depth-agnostic: with per-lane
    adaptive K a short lane's surplus draft writes (depth < batch width T)
    clip/wrap exactly like rejected full-depth drafts, and capacity is
    reserved for the worst-case k_max (engine ``_cap``), so committed slots
    are never displaced."""
    B, C = cache.shape[:2]
    T = blk.shape[1]
    rel = jnp.arange(C)[None, :] - lengths[:, None]           # (B,C)
    if wrap:
        rel = rel % C
    mask = (rel >= 0) & (rel < T)
    idx = jnp.clip(rel, 0, T - 1)
    idx = idx.reshape(idx.shape + (1,) * (cache.ndim - 2))
    src = jnp.take_along_axis(blk, idx, axis=1)
    mask = mask.reshape(mask.shape + (1,) * (cache.ndim - 2))
    return jnp.where(mask, src.astype(cache.dtype), cache)


def attn_layer_step(p, x, kcache, vcache, slot_pos, lengths, cfg: ModelConfig,
                    seg: Segment, aux, use_rope=True, kscale=None, vscale=None):
    """Block-decode attention layer against the cache.

    kcache/vcache: (B, C, KV, hd).  slot_pos: (B, C) int32 — absolute position
    stored in each slot (-1 = empty); for full caches slot_pos[b, j] = j when
    filled.  kscale/vscale: (B, C, KV) int8-cache scales when cfg.kv_quant.
    Returns (x, new_k, new_v, new_ks, new_vs, aux)."""
    B, T = x.shape[:2]
    C = kcache.shape[1]
    W = cfg.sliding_window if seg.kind == "local" else 0
    if seg.kind == "local" and cfg.rglru is not None:
        W = cfg.rglru.local_window
    from repro.launch.hints import hint
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    xn = hint(xn, None, None, "data")        # weight-stationary decode flow
    qpos = lengths[:, None] + jnp.arange(T)[None, :]          # (B,T)
    q, k, v = _qkv(p, xn, cfg)
    # cache I/O is batch-sharded: reshard the (tiny) q/k/v blocks, not the
    # (huge) cache or weights
    q = hint(q, "data", None, None, None)
    k = hint(k, "data", None, None, None)
    v = hint(v, "data", None, None, None)
    if use_rope:
        q = apply_rope(q, qpos, cfg.rope_theta)
        k = apply_rope(k, qpos, cfg.rope_theta)

    # iota-select write: slot s <- blk[(s - lengths) mod C] where that index
    # falls in [0, T).  Pure elementwise select, so a sequence-sharded cache
    # stays sharded (a scatter at traced per-seq indices would force GSPMD
    # to regather the whole cache — 10x per-device memory at 32k decode).
    # Only rings wrap; full caches clip out-of-capacity eager writes.
    wrap = W > 0
    new_ks = new_vs = None
    if cfg.kv_quant:
        kq, ks_blk = kv_quantize(k)
        vq, vs_blk = kv_quantize(v)
        new_k = spread_write(kcache, kq, lengths, wrap)
        new_v = spread_write(vcache, vq, lengths, wrap)
        new_ks = spread_write(kscale, ks_blk, lengths, wrap)
        new_vs = spread_write(vscale, vs_blk, lengths, wrap)
        k_eff = kv_dequantize(new_k, new_ks, x.dtype)
        v_eff = kv_dequantize(new_v, new_vs, x.dtype)
    else:
        new_k = spread_write(kcache, k, lengths, wrap)
        new_v = spread_write(vcache, v, lengths, wrap)
        k_eff, v_eff = new_k, new_v

    mask = (slot_pos[:, None, :] <= qpos[:, :, None]) & (slot_pos[:, None, :] >= 0)
    if W:
        mask &= slot_pos[:, None, :] > qpos[:, :, None] - W
    # flash-decode layout: scores stay sequence-sharded over "model"
    # (the Pallas decode_attention kernel implements the same blocking)
    out = attend(q, hint(k_eff, "data", "model", None, None),
                 hint(v_eff, "data", "model", None, None), mask)
    x = x + out.reshape(B, T, -1) @ p["wo"]
    if seg.cross:
        x = _cross_attn(p, x, p["_xk"], p["_xv"], cfg)        # injected below
    x, aux = _ffn(p, x, cfg, seg.ffn, aux, dropless=True)
    return x, new_k, new_v, new_ks, new_vs, aux


def attn_layer_step_paged(p, x, kcache, vcache, tbl, lengths, cfg: ModelConfig,
                          seg: Segment, aux, use_rope=True, kscale=None,
                          vscale=None):
    """Block-decode attention against the POOLED paged cache.

    kcache/vcache: (P, page_size, KV, hd) physical pages shared by every
    lane (page 0 = null page, never allocated).  tbl: (B, MPS) int32 block
    table — logical position t of lane b lives at physical slot
    ``tbl[b, t // ps] * ps + t % ps``; -1 entries clamp onto the null page
    so eager writes from dead lanes are harmless.  Speculative rollback is
    identical to the contiguous path: lengths simply don't advance past the
    accepted prefix and the stale slots are overwritten next block.
    Returns (x, new_k, new_v, new_ks, new_vs, aux)."""
    B, T = x.shape[:2]
    Pp, ps = kcache.shape[:2]
    MPS = tbl.shape[1]
    Lv = MPS * ps                                 # per-lane logical capacity
    from repro.launch.hints import hint
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    xn = hint(xn, None, None, "data")             # weight-stationary decode
    qpos = lengths[:, None] + jnp.arange(T)[None, :]              # (B, T)
    q, k, v = _qkv(p, xn, cfg)
    q = hint(q, "data", None, None, None)
    k = hint(k, "data", None, None, None)
    v = hint(v, "data", None, None, None)
    if use_rope:
        q = apply_rope(q, qpos, cfg.rope_theta)
        k = apply_rope(k, qpos, cfg.rope_theta)

    def flat(c):
        return c.reshape((Pp * ps,) + c.shape[2:])

    # eager paged write: scatter the T-token block through the block table.
    # Distinct lanes own disjoint pages, so indices never collide except on
    # the null page (garbage by construction, masked out of every read).
    from repro.serving.kv_pool import logical_to_physical
    _, wphys = logical_to_physical(tbl, qpos, ps)
    wphys = wphys.reshape(-1)

    def write(cache, blk):
        return flat(cache).at[wphys].set(
            blk.reshape((B * T,) + blk.shape[2:]).astype(cache.dtype)
        ).reshape(cache.shape)

    new_ks = new_vs = None
    if cfg.kv_quant:
        kq, ks_blk = kv_quantize(k)
        vq, vs_blk = kv_quantize(v)
        new_k, new_v = write(kcache, kq), write(vcache, vq)
        new_ks, new_vs = write(kscale, ks_blk), write(vscale, vs_blk)
    else:
        new_k, new_v = write(kcache, k), write(vcache, v)

    # gather this lane's logical view back out of the pool (the Pallas
    # paged_decode_attention kernel fetches the same tiles page-by-page via
    # a scalar-prefetched block table instead of materializing the view)
    j = jnp.arange(Lv)
    rpage, rphys = logical_to_physical(
        tbl, jnp.broadcast_to(j[None, :], (B, Lv)), ps)           # (B, Lv)
    k_eff = flat(new_k)[rphys]                                    # (B,Lv,KV,hd)
    v_eff = flat(new_v)[rphys]
    if cfg.kv_quant:
        k_eff = kv_dequantize(k_eff, flat(new_ks)[rphys], x.dtype)
        v_eff = kv_dequantize(v_eff, flat(new_vs)[rphys], x.dtype)
    slot_pos = jnp.where((rpage >= 0) & (j[None, :] < lengths[:, None] + T),
                         j[None, :], -1)
    mask = (slot_pos[:, None, :] <= qpos[:, :, None]) & (slot_pos[:, None, :] >= 0)
    out = attend(q, k_eff, v_eff, mask)
    x = x + out.reshape(B, T, -1) @ p["wo"]
    x, aux = _ffn(p, x, cfg, seg.ffn, aux, dropless=True)
    return x, new_k, new_v, new_ks, new_vs, aux


def mla_layer_step(p, x, ckv_cache, krope_cache, lengths, cfg, seg, aux):
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    qpos = lengths[:, None] + jnp.arange(x.shape[1])[None, :]
    out, new_ckv, new_krope = mla_mod.mla_step(
        p, xn, ckv_cache, krope_cache, lengths, cfg.num_heads, cfg.mla,
        qpos, cfg.rope_theta)
    x = x + out
    x, aux = _ffn(p, x, cfg, seg.ffn, aux, dropless=True)
    return x, new_ckv, new_krope, aux


# ---------------------------------------------------------------------------
# Segment execution (scan over stacked layers)
# ---------------------------------------------------------------------------

def run_segment_full(sp, x, cfg: ModelConfig, seg: Segment, positions,
                     prefix_len, enc_out, collect, remat=False):
    """Returns (x, contribs stacked (n,...), aux)."""
    T = x.shape[1]
    if seg.kind == "local":
        W = cfg.rglru.local_window if cfg.rglru is not None else cfg.sliding_window
        spec = MaskSpec(window=W, prefix_len=prefix_len)
    elif cfg.arch_type == "audio" and seg.kind == "attn" and enc_out is None:
        spec = MaskSpec(bidirectional=True)   # encoder self-attention
    else:
        spec = MaskSpec(prefix_len=prefix_len)
    use_rope = cfg.arch_type != "audio"

    from repro.launch.hints import hint

    def body(carry, lp):
        x, aux = carry
        # pin batch-sharded activations: XLA must FSDP-gather the weights
        # rather than regather the (much larger) activations.  Batch takes
        # BOTH axes when it divides (pure-FSDP training layout, §Perf H4) —
        # the tuple falls back to "data" alone otherwise (decode/prefill).
        x = hint(x, ("data", "model"), None, None)
        if seg.kind == "ssm":
            x, contrib = ssm_mod.ssm_forward_full(lp, x, cfg.ssm, cfg.norm_eps)
        elif seg.kind == "rglru":
            x, contrib = rglru_mod.rglru_forward_full(lp, x, cfg.rglru, cfg.norm_eps)
            x, aux = _ffn(lp, x, cfg, seg.ffn, aux)
        else:
            x, contrib, aux = attn_layer_full(lp, x, cfg, seg, positions, spec,
                                              enc_out, aux, use_rope, collect)
        x = hint(x, "data", None, None)
        if not collect:
            contrib = {}
        return (x, aux), contrib

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), contribs = jax.lax.scan(body, (x, jnp.float32(0.0)), sp)
    return x, contribs, aux


def run_segment_step(sp, x, seg_cache, cross_cache, lengths, cfg: ModelConfig,
                     seg: Segment, tbl=None):
    """Returns (x, new_seg_cache, candidates, aux).  `tbl` is the paged
    block table (B, MPS) when the cache is paged (seg_cache holds pooled
    "kp"/"vp" pages instead of per-lane "k"/"v")."""
    T = x.shape[1]
    aux0 = jnp.float32(0.0)
    use_rope = cfg.arch_type != "audio"

    if "kp" in seg_cache:                  # pooled paged full attention
        quant = cfg.kv_quant

        def body(carry, xs):
            x, aux = carry
            ks = vs = None
            lp, kc, vc = xs[:3]
            if quant:
                ks, vs = xs[3], xs[4]
            x, nk, nv, nks, nvs, aux = attn_layer_step_paged(
                lp, x, kc, vc, tbl, lengths, cfg, seg, aux, use_rope,
                kscale=ks, vscale=vs)
            ys = (nk, nv) + ((nks, nvs) if quant else ())
            return (x, aux), ys

        xs = (sp, seg_cache["kp"], seg_cache["vp"])
        if quant:
            xs = xs + (seg_cache["ksp"], seg_cache["vsp"])
        (x, aux), ys = jax.lax.scan(body, (x, aux0), xs)
        new_c = {"kp": ys[0], "vp": ys[1]}
        if quant:
            new_c["ksp"], new_c["vsp"] = ys[2], ys[3]
        return x, new_c, {}, aux

    if seg.kind == "ssm":
        def body(carry, xs):
            x, aux = carry
            lp, conv, state = xs
            x, cand = ssm_mod.ssm_step(lp, x, {"conv": conv, "state": state},
                                       cfg.ssm, cfg.norm_eps)
            return (x, aux), cand
        (x, aux), cands = jax.lax.scan(
            body, (x, aux0), (sp, seg_cache["conv"], seg_cache["state"]))
        return x, seg_cache, cands, aux

    if seg.kind == "rglru":
        def body(carry, xs):
            x, aux = carry
            lp, conv, state = xs
            x, cand = rglru_mod.rglru_step(lp, x, {"conv": conv, "state": state},
                                           cfg.rglru, cfg.norm_eps)
            x, aux = _ffn(lp, x, cfg, seg.ffn, aux, dropless=True)
            return (x, aux), cand
        (x, aux), cands = jax.lax.scan(
            body, (x, aux0), (sp, seg_cache["conv"], seg_cache["state"]))
        return x, seg_cache, cands, aux

    if cfg.mla is not None:
        def body(carry, xs):
            x, aux = carry
            lp, ckv, krope = xs
            x, nckv, nkrope, aux = mla_layer_step(lp, x, ckv, krope, lengths,
                                                  cfg, seg, aux)
            return (x, aux), (nckv, nkrope)
        (x, aux), (nckv, nkrope) = jax.lax.scan(
            body, (x, aux0), (sp, seg_cache["ckv"], seg_cache["krope"]))
        return x, {"ckv": nckv, "krope": nkrope, "pos": seg_cache["pos"]}, {}, aux

    # attention (full or local ring)
    C = seg_cache["k"].shape[2]
    W = 0
    if seg.kind == "local":
        W = cfg.rglru.local_window if cfg.rglru is not None else cfg.sliding_window
    qpos = lengths[:, None] + jnp.arange(T)[None, :]
    rel = jnp.arange(C)[None, :] - lengths[:, None]
    if W:
        rel = rel % C
    new_pos = jnp.where((rel >= 0) & (rel < T), lengths[:, None] + rel,
                        seg_cache["pos"])

    quant = cfg.kv_quant

    def body(carry, xs):
        x, aux = carry
        ks = vs = None
        if seg.cross:
            lp, kc, vc, xk, xv = xs[:5]
            lp = dict(lp, _xk=xk, _xv=xv)
            if quant:
                ks, vs = xs[5], xs[6]
        else:
            lp, kc, vc = xs[:3]
            if quant:
                ks, vs = xs[3], xs[4]
        x, nk, nv, nks, nvs, aux = attn_layer_step(
            lp, x, kc, vc, new_pos, lengths, cfg, seg, aux, use_rope,
            kscale=ks, vscale=vs)
        ys = (nk, nv) + ((nks, nvs) if quant else ())
        return (x, aux), ys

    xs = (sp, seg_cache["k"], seg_cache["v"])
    if seg.cross:
        xs = xs + (cross_cache["xk"], cross_cache["xv"])
    if quant:
        xs = xs + (seg_cache["ks"], seg_cache["vs"])
    (x, aux), ys = jax.lax.scan(body, (x, aux0), xs)
    new_c = {"k": ys[0], "v": ys[1], "pos": new_pos}
    if quant:
        new_c["ks"], new_c["vs"] = ys[2], ys[3]
    return x, new_c, {}, aux


# ---------------------------------------------------------------------------
# Cache construction / commit
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, B: int, max_len: int, dtype=None) -> dict:
    """Cache pytree for the full stack (all segments, [0, L))."""
    dtype = dtype or cfg.jnp_dtype
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    segs = {}
    for seg in model_segments(cfg):
        n = seg.n
        if seg.kind == "ssm":
            c = ssm_mod.init_ssm_cache(n, B, cfg.d_model, cfg.ssm, dtype)
        elif seg.kind == "rglru":
            c = rglru_mod.init_rglru_cache(n, B, cfg.d_model, cfg.rglru, dtype)
        elif cfg.mla is not None:
            m = cfg.mla
            c = {"ckv": jnp.zeros((n, B, max_len, m.kv_lora_rank), dtype),
                 "krope": jnp.zeros((n, B, max_len, m.qk_rope_head_dim), dtype),
                 "pos": jnp.full((B, max_len), -1, jnp.int32)}
        else:
            if seg.kind == "local":
                W = cfg.rglru.local_window if cfg.rglru is not None else cfg.sliding_window
                C = W + RING_SLACK
            else:
                C = max_len
            kv_dtype = jnp.int8 if cfg.kv_quant else dtype
            c = {"k": jnp.zeros((n, B, C, KV, hd), kv_dtype),
                 "v": jnp.zeros((n, B, C, KV, hd), kv_dtype),
                 "pos": jnp.full((B, C), -1, jnp.int32)}
            if cfg.kv_quant:
                c["ks"] = jnp.zeros((n, B, C, KV), jnp.float32)
                c["vs"] = jnp.zeros((n, B, C, KV), jnp.float32)
        if seg.cross:
            F = cfg.encoder.num_frames
            c["xk"] = jnp.zeros((n, B, F, cfg.num_heads, hd), dtype)
            c["xv"] = jnp.zeros((n, B, F, cfg.num_heads, hd), dtype)
        segs[seg.name] = c
    return {"lengths": jnp.zeros((B,), jnp.int32), "segs": segs}


def init_paged_cache(cfg: ModelConfig, B: int, num_pages: int, page_size: int,
                     max_pages_per_slot: int, dtype=None) -> dict:
    """Paged cache pytree: full-attention KV pooled into `num_pages` shared
    fixed-size pages (+1 physical null page at index 0), addressed per lane
    through the block table ``cache["tbl"]`` (B, max_pages_per_slot) int32
    (-1 = unmapped).  Sliding-window rings and SSD/RG-LRU states stay
    per-slot — they are O(window)/O(1) per lane and gain nothing from
    paging.  Page ownership / recycling is host-side (``serving.kv_pool``).
    """
    dtype = dtype or cfg.jnp_dtype
    if cfg.mla is not None:
        raise NotImplementedError("paged KV: MLA latent caches not supported")
    if cfg.encoder is not None:
        raise NotImplementedError("paged KV: cross-attention caches not supported")
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    Pp = num_pages + 1                       # physical pages incl. null page
    segs = {}
    for seg in model_segments(cfg):
        n = seg.n
        if seg.kind == "ssm":
            c = ssm_mod.init_ssm_cache(n, B, cfg.d_model, cfg.ssm, dtype)
        elif seg.kind == "rglru":
            c = rglru_mod.init_rglru_cache(n, B, cfg.d_model, cfg.rglru, dtype)
        elif seg.kind == "local":
            W = cfg.rglru.local_window if cfg.rglru is not None else cfg.sliding_window
            C = W + RING_SLACK
            kv_dtype = jnp.int8 if cfg.kv_quant else dtype
            c = {"k": jnp.zeros((n, B, C, KV, hd), kv_dtype),
                 "v": jnp.zeros((n, B, C, KV, hd), kv_dtype),
                 "pos": jnp.full((B, C), -1, jnp.int32)}
            if cfg.kv_quant:
                c["ks"] = jnp.zeros((n, B, C, KV), jnp.float32)
                c["vs"] = jnp.zeros((n, B, C, KV), jnp.float32)
        else:                                # full attention -> page pool
            kv_dtype = jnp.int8 if cfg.kv_quant else dtype
            c = {"kp": jnp.zeros((n, Pp, page_size, KV, hd), kv_dtype),
                 "vp": jnp.zeros((n, Pp, page_size, KV, hd), kv_dtype)}
            if cfg.kv_quant:
                c["ksp"] = jnp.zeros((n, Pp, page_size, KV), jnp.float32)
                c["vsp"] = jnp.zeros((n, Pp, page_size, KV), jnp.float32)
        segs[seg.name] = c
    return {"lengths": jnp.zeros((B,), jnp.int32),
            "tbl": jnp.full((B, max_pages_per_slot), -1, jnp.int32),
            "segs": segs}


def map_slot_pages(cache: dict, slot, row: jax.Array) -> dict:
    """Point lane `slot`'s block-table row at physical pages `row`
    (MPS,) int32, -1-padded.  Pure table write — no KV moves."""
    tbl = jax.lax.dynamic_update_slice(cache["tbl"], row[None, :].astype(jnp.int32),
                                       (slot, 0))
    return dict(cache, tbl=tbl)


def set_block_tables(cache: dict, tbl: jax.Array) -> dict:
    """Replace the WHOLE block table (B, MPS) in one device op.  The serving
    engine keeps a host-side mirror of the table and batches every per-lane
    page-growth row update of a tick into this single push, instead of one
    ``map_slot_pages`` dispatch per lane per allocation.  No KV moves."""
    return dict(cache, tbl=tbl.astype(jnp.int32))


def fill_cache_from_full(cfg: ModelConfig, cache: dict, contribs: dict,
                         T: int) -> dict:
    """Scatter prefill contributions (stacked (n,B,T,...)) into the cache.
    All sequences are assumed fully packed (length T)."""
    new_segs = dict(cache["segs"])
    for seg in model_segments(cfg):
        con = contribs.get(seg.name)
        if con is None or not con:
            continue
        c = dict(new_segs[seg.name])
        if seg.kind in ("ssm", "rglru"):
            c["conv"], c["state"] = con["conv"], con["state"]
        elif cfg.mla is not None and seg.kind in ("attn", "local"):
            S = c["ckv"].shape[2]
            c["ckv"] = jax.lax.dynamic_update_slice(
                c["ckv"], con["ckv"].astype(c["ckv"].dtype), (0, 0, 0, 0))
            c["krope"] = jax.lax.dynamic_update_slice(
                c["krope"], con["krope"].astype(c["krope"].dtype), (0, 0, 0, 0))
            c["pos"] = c["pos"].at[:, :T].set(jnp.arange(T)[None, :])
        else:
            Cap = c["k"].shape[2]
            kv_k, kv_v = con["k"], con["v"]
            if cfg.kv_quant:
                kv_k, ks_all = kv_quantize(kv_k)
                kv_v, vs_all = kv_quantize(kv_v)
            if seg.kind == "local" and T > Cap:
                keep = Cap
                pos = jnp.arange(T - keep, T)
                sl = pos % Cap
                c["k"] = c["k"].at[:, :, sl].set(kv_k[:, :, -keep:].astype(c["k"].dtype))
                c["v"] = c["v"].at[:, :, sl].set(kv_v[:, :, -keep:].astype(c["v"].dtype))
                c["pos"] = c["pos"].at[:, sl].set(pos[None, :])
                if cfg.kv_quant:
                    c["ks"] = c["ks"].at[:, :, sl].set(ks_all[:, :, -keep:])
                    c["vs"] = c["vs"].at[:, :, sl].set(vs_all[:, :, -keep:])
            else:
                c["k"] = jax.lax.dynamic_update_slice(
                    c["k"], kv_k.astype(c["k"].dtype), (0, 0, 0, 0, 0))
                c["v"] = jax.lax.dynamic_update_slice(
                    c["v"], kv_v.astype(c["v"].dtype), (0, 0, 0, 0, 0))
                c["pos"] = c["pos"].at[:, :T].set(jnp.arange(T)[None, :])
                if cfg.kv_quant:
                    c["ks"] = jax.lax.dynamic_update_slice(
                        c["ks"], ks_all, (0, 0, 0, 0))
                    c["vs"] = jax.lax.dynamic_update_slice(
                        c["vs"], vs_all, (0, 0, 0, 0))
        if seg.cross and "xk" in con:
            c["xk"], c["xv"] = (con["xk"].astype(c["xk"].dtype),
                                con["xv"].astype(c["xv"].dtype))
        new_segs[seg.name] = c
    B = cache["lengths"].shape[0]
    return {"lengths": jnp.full((B,), T, jnp.int32), "segs": new_segs}


def _slot_axis(leaf_name: str) -> int:
    """Batch axis of a per-segment cache leaf: `pos` maps (B, C); everything
    else is layer-stacked (n, B, ...)."""
    return 0 if leaf_name == "pos" else 1


def _insert_paged_seg(cfg: ModelConfig, seg_c: dict, src_c: dict,
                      tbl: jax.Array, slot, src_slot: int = 0) -> dict:
    """Splice a contiguous prefill lane into the slot's mapped pages: a
    block-table-indexed scatter of the source KV into the shared pool.
    Source positions past the mapped region clamp onto the null page."""
    from repro.serving.kv_pool import logical_to_physical
    Pp, ps = seg_c["kp"].shape[1:3]
    C_src = src_c["k"].shape[2]
    row = jax.lax.dynamic_slice_in_dim(tbl, slot, 1, 0)           # (1, MPS)
    _, phys = logical_to_physical(row, jnp.arange(C_src)[None, :], ps)
    phys = phys[0]

    def splice(pooled, src_leaf):
        piece = jax.lax.dynamic_slice_in_dim(src_leaf, src_slot, 1, 1)[:, 0]
        flatp = pooled.reshape((pooled.shape[0], Pp * ps) + pooled.shape[3:])
        return flatp.at[:, phys].set(piece.astype(pooled.dtype)
                                     ).reshape(pooled.shape)

    out = dict(seg_c, kp=splice(seg_c["kp"], src_c["k"]),
               vp=splice(seg_c["vp"], src_c["v"]))
    if cfg.kv_quant:
        out["ksp"] = splice(seg_c["ksp"], src_c["ks"])
        out["vsp"] = splice(seg_c["vsp"], src_c["vs"])
    return out


def copy_page(cache: dict, src_page, dst_page) -> dict:
    """Copy-on-write splice: duplicate physical page `src_page`'s KV (and
    int8 scales) onto page `dst_page` in every paged full-attention
    segment, in one device op per leaf.  Used by prefix-cache admission
    when a cached prompt prefix ends mid-page: the partially-matching
    cached page is copied into the lane's freshly allocated page, after
    which the lane appends through its own block table without ever
    touching the shared original.  Slots past the matched prefix carry
    donor garbage — overwritten by the consumer's tail prefill before any
    read, exactly like uninitialized pool slots.  `src_page`/`dst_page`
    may be traced scalars; pass 0 (the null page) for both to make the
    whole op a harmless no-op inside a jitted admission function."""
    segs = {}
    for name, seg_c in cache["segs"].items():
        if "kp" not in seg_c:
            segs[name] = seg_c
            continue
        out = dict(seg_c)
        for key in ("kp", "vp", "ksp", "vsp"):
            leaf = seg_c.get(key)
            if leaf is None:
                continue
            page = jax.lax.dynamic_slice_in_dim(leaf, src_page, 1, axis=1)
            out[key] = jax.lax.dynamic_update_slice_in_dim(
                leaf, page, dst_page, axis=1)
        segs[name] = out
    return dict(cache, segs=segs)


def insert_slot(cfg: ModelConfig, cache: dict, src: Optional[dict], slot,
                src_slot: int = 0, shared_len=None) -> dict:
    """Continuous-batching cache surgery: copy sequence lane `src_slot` of
    cache `src` (e.g. a freshly prefilled B=1 contiguous cache) into lane
    `slot` of a live batched cache.  The source may be PARTIALLY BUILT: its
    per-slot sequence capacities (attention KV, quant scales, slot
    positions, MLA latents) only need to cover what was actually prefilled
    — e.g. a chunk-sized scratch holding the first prefill chunk — and are
    spliced into the lane's prefix; the destination lane must have been
    reset (``reset_slot``), so its tail is already inert (pos = -1, zero
    states).  Constant-size leaves (ring buffers, stateful-mixer
    conv/state, cross-attention KV) must share capacities exactly.  Paged
    full-attention segments instead scatter the source KV through the
    slot's block-table row (map the pages with ``map_slot_pages`` first).
    `slot` may be a traced scalar, so admission jits once per prompt (or
    chunk) shape.

    ``src=None`` (table-splice-without-copy): prefix-cache warm admission.
    The lane's first `shared_len` logical tokens already live in SHARED
    physical pages that ``map_slot_pages`` spliced into its block-table
    row, so the insert is pure bookkeeping — set the lane length to
    `shared_len` (traced ok) and move NO KV whatsoever; in particular
    nothing is ever scattered over the shared pages, which other lanes may
    be reading concurrently.  Only a pure paged full-attention cache
    qualifies (ring/SSM/RG-LRU segments hold per-lane state that cannot be
    shared by content)."""
    tbl = cache.get("tbl")
    if src is None:
        if shared_len is None:
            raise ValueError("insert_slot(src=None) needs shared_len")
        if tbl is None or any("kp" not in c for c in cache["segs"].values()):
            raise NotImplementedError(
                "table-splice admission (src=None) requires a pure paged "
                "full-attention cache — per-lane segment state cannot be "
                "prefix-shared")
        ln = jnp.asarray(shared_len, jnp.int32).reshape(1)
        lengths = jax.lax.dynamic_update_slice_in_dim(
            cache["lengths"], ln, slot, 0)
        return dict(cache, lengths=lengths)
    new_segs = {}
    for name, seg_c in cache["segs"].items():
        src_c = src["segs"][name]
        if "kp" in seg_c:
            new_segs[name] = _insert_paged_seg(cfg, seg_c, src_c, tbl, slot,
                                               src_slot)
            continue
        out = {}
        for kname, leaf in seg_c.items():
            ax = _slot_axis(kname)
            piece = jax.lax.dynamic_slice_in_dim(src_c[kname], src_slot, 1, ax)
            starts = [0] * leaf.ndim
            starts[ax] = slot
            out[kname] = jax.lax.dynamic_update_slice(
                leaf, piece.astype(leaf.dtype), tuple(starts))
        new_segs[name] = out
    ln = jax.lax.dynamic_slice_in_dim(src["lengths"], src_slot, 1, 0)
    lengths = jax.lax.dynamic_update_slice_in_dim(cache["lengths"], ln, slot, 0)
    out = {"lengths": lengths, "segs": new_segs}
    if tbl is not None:
        out["tbl"] = tbl
    return out


def reset_slot(cfg: ModelConfig, cache: dict, slot) -> dict:
    """Evict sequence lane `slot`: length 0, attention slots emptied
    (pos = -1), KV and stateful-mixer states zeroed — an inert lane that a
    later ``insert_slot`` can reuse.  Other lanes are untouched bit-for-bit.
    Paged segments need no KV work at all: the lane's block-table row is
    unmapped (-1) and the physical pages go back to the host-side pool —
    copy-free eviction."""
    new_segs = {}
    for name, seg_c in cache["segs"].items():
        if "kp" in seg_c:                    # pool pages are recycled, not zeroed
            new_segs[name] = seg_c
            continue
        out = {}
        for kname, leaf in seg_c.items():
            ax = _slot_axis(kname)
            shape = leaf.shape[:ax] + (1,) + leaf.shape[ax + 1:]
            fill = -1 if kname == "pos" else 0
            piece = jnp.full(shape, fill, leaf.dtype)
            out[kname] = jax.lax.dynamic_update_slice_in_dim(leaf, piece,
                                                             slot, ax)
        new_segs[name] = out
    lengths = jax.lax.dynamic_update_slice_in_dim(
        cache["lengths"], jnp.zeros((1,), jnp.int32), slot, 0)
    out = {"lengths": lengths, "segs": new_segs}
    if "tbl" in cache:
        MPS = cache["tbl"].shape[1]
        out["tbl"] = jax.lax.dynamic_update_slice(
            cache["tbl"], jnp.full((1, MPS), -1, jnp.int32), (slot, 0))
    return out


def commit_cache(cfg: ModelConfig, cache: dict, cands: dict,
                 accept: jax.Array) -> dict:
    """Advance the cache by `accept` (B,) committed tokens; select stateful
    candidate states at index accept-1 (no-op rows where accept == 0).

    Ragged-depth audit (adaptive per-lane K): everything here is already
    per-lane — `accept` may be any value in [0, T] independently per batch
    row, the gather at accept-1 never reads past the candidate block, and
    rollback of the unaccepted tail is pure length truncation (the eager
    writes beyond ``lengths + accept`` are excluded from attention by the
    ``pos <= qpos`` mask and overwritten by the next block).  A lane whose
    depth k is below the batch draft width K commits at most k+1 tokens and
    its extra K-k eager writes are exactly the rejected-draft garbage this
    rollback rule already handles — no adaptive-depth special case."""
    new_segs = dict(cache["segs"])
    for seg in model_segments(cfg):
        cand = cands.get(seg.name)
        if not cand:
            continue
        c = dict(new_segs[seg.name])
        idx = jnp.maximum(accept - 1, 0)                    # (B,)
        keep_old = (accept == 0)

        def select(cand_arr, old):
            # cand_arr (n,B,T,...) -> per-batch gather at index `idx` on axis 2
            B = idx.shape[0]
            gidx = idx.reshape((1, B) + (1,) * (cand_arr.ndim - 2))
            sel = jnp.take_along_axis(cand_arr, gidx, axis=2).squeeze(2)
            mask_shape = (1, B) + (1,) * (sel.ndim - 2)
            return jnp.where(keep_old.reshape(mask_shape), old, sel.astype(old.dtype))

        c["conv"] = select(cand["conv"], c["conv"])
        c["state"] = select(cand["state"], c["state"])
        new_segs[seg.name] = c
    out = {"lengths": cache["lengths"] + accept, "segs": new_segs}
    if "tbl" in cache:
        out["tbl"] = cache["tbl"]
    return out


# ---------------------------------------------------------------------------
# Stack-level entry points
# ---------------------------------------------------------------------------

def forward_full(params_segs: dict, x: jax.Array, cfg: ModelConfig, lo: int,
                 hi: int, positions=None, prefix_len: int = 0, enc_out=None,
                 collect: bool = False, remat: bool = False):
    """Run layers [lo, hi) over a full sequence.  Returns (x, contribs, aux)."""
    B, T = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    contribs, aux = {}, jnp.float32(0.0)
    for seg in segments_in_range(cfg, lo, hi):
        x, con, a = run_segment_full(params_segs[seg.name], x, cfg, seg,
                                     positions, prefix_len, enc_out, collect,
                                     remat)
        contribs[seg.name] = con
        aux = aux + a
    return x, contribs, aux


def forward_step(params_segs: dict, x: jax.Array, cfg: ModelConfig, cache: dict,
                 lo: int, hi: int):
    """Run layers [lo, hi) on a T-token block against the cache.

    Returns (x, new_cache, cands, aux).  new_cache has attention caches
    updated eagerly; stateful segments updated only via `commit_cache`."""
    lengths = cache["lengths"]
    new_segs = dict(cache["segs"])
    cands, aux = {}, jnp.float32(0.0)
    for seg in segments_in_range(cfg, lo, hi):
        seg_cache = cache["segs"][seg.name]
        x, new_c, cand, a = run_segment_step(
            params_segs[seg.name], x, seg_cache, seg_cache, lengths, cfg, seg,
            tbl=cache.get("tbl"))
        new_segs[seg.name] = {**seg_cache, **new_c}
        if cand:
            cands[seg.name] = cand
        aux = aux + a
    out = {"lengths": lengths, "segs": new_segs}
    if "tbl" in cache:
        out["tbl"] = cache["tbl"]
    return x, out, cands, aux
