"""Mamba-2 SSD block [arXiv:2405.21060].

Full-sequence path uses the chunked state-space-duality form (intra-chunk
quadratic attention-like term on the MXU + inter-chunk linear recurrence) —
the same decomposition the Pallas kernel (`repro/kernels/ssd_scan.py`)
implements on TPU.  The decode path is the per-step recurrence
``h_t = exp(dt*A) h_{t-1} + dt * B_t ⊗ x_t``;  ``step`` returns the state
after *every* token in the block so the speculative commit can select the
state at the accepted length (SSM states cannot be rolled back by masking
the way KV caches can).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import conv1d_causal, dense_init, rms_norm, split_keys


def ssm_dims(d_model: int, s: SSMConfig):
    d_in = s.expand * d_model
    H = d_in // s.head_dim
    conv_dim = d_in + 2 * s.ngroups * s.d_state
    proj_dim = 2 * d_in + 2 * s.ngroups * s.d_state + H
    return d_in, H, conv_dim, proj_dim


def init_ssm(key, n: int, d: int, s: SSMConfig, dtype) -> dict:
    d_in, H, conv_dim, proj_dim = ssm_dims(d, s)
    ks = split_keys(key, 4)
    return {
        "ln1": jnp.zeros((n, d), jnp.float32),
        "in_proj": dense_init(ks[0], (n, d, proj_dim), dtype),
        "conv_w": dense_init(ks[1], (n, s.d_conv, conv_dim), jnp.float32, scale=0.5),
        "A_log": jnp.tile(jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)), (n, 1)),
        "D": jnp.ones((n, H), jnp.float32),
        "dt_bias": jnp.tile(jnp.log(jnp.expm1(jnp.linspace(1e-3, 0.1, H))).astype(jnp.float32), (n, 1)),
        "norm_w": jnp.zeros((n, d_in), jnp.float32),
        "out_proj": dense_init(ks[2], (n, d_in, d), dtype),
    }


def _split_proj(zxbcdt, d_in, G, ds, H):
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:d_in + d_in + 2 * G * ds]
    dt = zxbcdt[..., -H:]
    return z, xBC, dt


def _split_xbc(xBC, d_in, G, ds, H, hd):
    B_, T = xBC.shape[0], xBC.shape[1]
    xh = xBC[..., :d_in].reshape(B_, T, H, hd)
    Bc = xBC[..., d_in:d_in + G * ds].reshape(B_, T, G, ds)
    Cc = xBC[..., d_in + G * ds:].reshape(B_, T, G, ds)
    return xh, Bc, Cc


def ssd_chunked(xh, Bc, Cc, dt, A, chunk: int, h0=None):
    """Chunked SSD scan (pure-jnp oracle shared with the Pallas kernel).

    xh (B,T,H,hd), Bc/Cc (B,T,G,ds), dt (B,T,H) [post-softplus], A (H,) < 0.
    Returns (y (B,T,H,hd), final_state (B,H,hd,ds)).  T % chunk == 0.
    """
    B_, T, H, hd = xh.shape
    G, ds = Bc.shape[2], Bc.shape[3]
    nc = T // chunk
    rep = H // G
    f32 = jnp.float32

    # one chunk in flight at a time (lax.scan): the (B,Q,Q,H) intra-chunk
    # decay tensor is the working set — materializing it for all chunks at
    # once would be O(T/Q) larger (1 TB at 32k prefill).
    xc = jnp.moveaxis(xh.reshape(B_, nc, chunk, H, hd), 1, 0).astype(f32)
    Bcc = jnp.moveaxis(jnp.repeat(Bc.reshape(B_, nc, chunk, G, ds), rep,
                                  axis=3), 1, 0).astype(f32)
    Ccc = jnp.moveaxis(jnp.repeat(Cc.reshape(B_, nc, chunk, G, ds), rep,
                                  axis=3), 1, 0).astype(f32)
    dtc = jnp.moveaxis(dt.reshape(B_, nc, chunk, H), 1, 0).astype(f32)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_fn(h, inp):
        x_, B__, C__, dt_ = inp                            # (B,Q,H,hd) etc.
        dA = dt_ * A[None, None, :]                        # (B,Q,H)
        cum = jnp.cumsum(dA, axis=1)
        seg = cum[:, :, None, :] - cum[:, None, :, :]      # (B,Q,Q,H)
        decay = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bihs,bjhs->bijh", C__, B__)
        att = cb * decay * dt_[:, None, :, :]
        y = jnp.einsum("bijh,bjhd->bihd", att, x_)
        y = y + jnp.einsum("bihs,bhds,bih->bihd", C__, h, jnp.exp(cum))
        dec_out = jnp.exp(cum[:, -1:, :] - cum) * dt_      # (B,Q,H)
        chunk_state = jnp.einsum("bjh,bjhs,bjhd->bhds", dec_out, B__, x_)
        h = h * jnp.exp(cum[:, -1])[:, :, None, None] + chunk_state
        return h, y

    h_init = jnp.zeros((B_, H, hd, ds), f32) if h0 is None else h0.astype(f32)
    h_final, ys = jax.lax.scan(chunk_fn, h_init, (xc, Bcc, Ccc, dtc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B_, T, H, hd)
    return y, h_final


def ssm_forward_full(p: dict, x: jax.Array, s: SSMConfig, norm_eps: float,
                     conv_state=None, h0=None):
    """Full-sequence Mamba-2 block.  x (B,T,d).  Returns (y, cache_contrib)."""
    d = x.shape[-1]
    d_in, H, conv_dim, _ = ssm_dims(d, s)
    G, ds, hd = s.ngroups, s.d_state, s.head_dim
    xn = rms_norm(x, p["ln1"], norm_eps)
    zxbcdt = xn @ p["in_proj"]
    z, xBC, dt = _split_proj(zxbcdt, d_in, G, ds, H)
    xBC, conv_state = conv1d_causal(xBC, p["conv_w"], conv_state)
    xBC = jax.nn.silu(xBC)
    xh, Bc, Cc = _split_xbc(xBC, d_in, G, ds, H, hd)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    T = x.shape[1]
    chunk = min(s.chunk_size, T)
    pad = (-T) % chunk
    if pad:
        padf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        xh, Bc, Cc, dtp = padf(xh), padf(Bc), padf(Cc), padf(dtp)
    y, h_final = ssd_chunked(xh, Bc, Cc, dtp, A, chunk, h0=h0)
    y = y[:, :T]
    y = y + xh[:, :T].astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(x.shape[0], T, d_in)
    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                 p["norm_w"], norm_eps)
    out = y @ p["out_proj"]
    return x + out, {"conv": conv_state, "state": h_final}


def ssm_step(p: dict, x: jax.Array, cache: dict, s: SSMConfig, norm_eps: float):
    """Block decode: x (B,T,d) with T small (k_spec+1).

    Returns (y (B,T,d), candidates) where candidates holds the conv window
    and SSD state after each of the T steps (for speculative commit-select).
    """
    B_, T, d = x.shape
    d_in, H, conv_dim, _ = ssm_dims(d, s)
    G, ds, hd = s.ngroups, s.d_state, s.head_dim
    xn = rms_norm(x, p["ln1"], norm_eps)
    zxbcdt = xn @ p["in_proj"]
    z, xBC, dt = _split_proj(zxbcdt, d_in, G, ds, H)
    A = -jnp.exp(p["A_log"])

    def step_fn(carry, inp):
        conv_st, h = carry
        xbc_t, dt_t = inp                                   # (B,conv_dim), (B,H)
        win = jnp.concatenate([conv_st, xbc_t[:, None]], axis=1)  # (B,cw,conv)
        cw = p["conv_w"].shape[0]
        y = jnp.sum(win.astype(jnp.float32) * p["conv_w"][None], axis=1)
        y = jax.nn.silu(y).astype(x.dtype)
        xh = y[:, :d_in].reshape(B_, H, hd)
        Bc = y[:, d_in:d_in + G * ds].reshape(B_, G, ds)
        Cc = y[:, d_in + G * ds:].reshape(B_, G, ds)
        rep = H // G
        Bch = jnp.repeat(Bc, rep, axis=1).astype(jnp.float32)
        Cch = jnp.repeat(Cc, rep, axis=1).astype(jnp.float32)
        dtp = jax.nn.softplus(dt_t.astype(jnp.float32) + p["dt_bias"])
        da = jnp.exp(dtp * A[None, :])                      # (B,H)
        h = h * da[..., None, None] + jnp.einsum(
            "bh,bhs,bhd->bhds", dtp, Bch, xh.astype(jnp.float32))
        yt = jnp.einsum("bhs,bhds->bhd", Cch, h)
        yt = yt + xh.astype(jnp.float32) * p["D"][None, :, None]
        new_conv = win[:, 1:]
        return (new_conv, h), (yt, new_conv, h)

    (_, _), (ys, convs, hs) = jax.lax.scan(
        step_fn, (cache["conv"], cache["state"]),
        (jnp.moveaxis(xBC, 1, 0), jnp.moveaxis(dt, 1, 0)))
    ys = jnp.moveaxis(ys, 0, 1).reshape(B_, T, d_in)        # (B,T,d_in)
    y = rms_norm((ys * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                 p["norm_w"], norm_eps)
    out = x + y @ p["out_proj"]
    cand = {"conv": jnp.moveaxis(convs, 0, 1),              # (B,T,cw-1,conv_dim)
            "state": jnp.moveaxis(hs, 0, 1)}                # (B,T,H,hd,ds)
    return out, cand


def init_ssm_cache(n: int, B: int, d: int, s: SSMConfig, dtype):
    d_in, H, conv_dim, _ = ssm_dims(d, s)
    return {"conv": jnp.zeros((n, B, s.d_conv - 1, conv_dim), dtype),
            "state": jnp.zeros((n, B, H, s.head_dim, s.d_state), jnp.float32)}
