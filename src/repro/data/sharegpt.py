"""ShareGPT-style prompt loading with an offline byte-level tokenizer.

The paper trains DVI on 2,000 ShareGPT prompts.  This container has no
network access and no HF tokenizers, so we provide: (a) a JSONL loader for
a local ShareGPT dump if one exists, and (b) a deterministic byte-level
tokenizer that hashes UTF-8 bytes into the model vocabulary — enough to
drive the online-learning pipeline with real-text-shaped streams.
"""
from __future__ import annotations

import json
import os
from typing import List, Optional

import numpy as np


class ByteTokenizer:
    """Bytes -> vocab ids (2..vocab).  0 = pad, 1 = eos."""

    def __init__(self, vocab_size: int):
        self.vocab = vocab_size

    def encode(self, text: str, max_len: Optional[int] = None) -> np.ndarray:
        b = np.frombuffer(text.encode("utf-8"), np.uint8).astype(np.int64)
        ids = 2 + (b * 2654435761 % (self.vocab - 2))
        if max_len is not None:
            ids = ids[:max_len]
        return ids.astype(np.int32)


def load_sharegpt_prompts(path: str, n: int, tokenizer: ByteTokenizer,
                          prompt_len: int = 64) -> Optional[np.ndarray]:
    """Load n prompts from a ShareGPT JSONL/JSON dump; None if absent."""
    if not os.path.exists(path):
        return None
    prompts: List[np.ndarray] = []
    with open(path) as f:
        if path.endswith(".jsonl"):
            records = (json.loads(line) for line in f)
        else:
            records = json.load(f)
        for rec in records:
            convs = rec.get("conversations", [])
            text = " ".join(c.get("value", "") for c in convs
                            if c.get("from") in ("human", "user"))
            if not text:
                continue
            ids = tokenizer.encode(text, prompt_len)
            if len(ids) < prompt_len:
                continue
            prompts.append(ids)
            if len(prompts) >= n:
                break
    if not prompts:
        return None
    return np.stack(prompts)
