from repro.data.synthetic import SyntheticTasks, TASK_CATEGORIES
from repro.data.sharegpt import load_sharegpt_prompts, ByteTokenizer

__all__ = ["SyntheticTasks", "TASK_CATEGORIES", "load_sharegpt_prompts",
           "ByteTokenizer"]
