"""Synthetic task-structured prompt streams (Spec-Bench-like suite).

Six categories mirroring Spec-Bench (MT-Bench, Translation, Summarization,
QA, Math, RAG).  Each category is a seeded sparse Markov chain over a
category-specific token subrange, so categories have distinct local lexical
structure — drafters trained on one category's stream transfer imperfectly
to others, reproducing the paper's distribution-sensitivity discussion.
"""
from __future__ import annotations

import numpy as np

TASK_CATEGORIES = ("mt_bench", "translation", "summarization", "qa", "math", "rag")


class SyntheticTasks:
    def __init__(self, vocab_size: int, seed: int = 0, branching: int = 4):
        self.vocab = vocab_size
        self.rng = np.random.default_rng(seed)
        self.branching = branching
        # reserve 0 = pad, 1 = eos
        lo, hi = 2, vocab_size
        span = (hi - lo) // len(TASK_CATEGORIES)
        self.ranges = {}
        self.next_tokens = {}
        self.next_probs = {}
        for ci, cat in enumerate(TASK_CATEGORIES):
            r0 = lo + ci * span
            r1 = r0 + span
            self.ranges[cat] = (r0, r1)
            n = r1 - r0
            # sparse transition structure: each token has `branching` successors
            succ = self.rng.integers(0, n, size=(n, branching))
            probs = self.rng.dirichlet(np.ones(branching) * 0.5, size=n)
            self.next_tokens[cat] = succ
            self.next_probs[cat] = probs

    def sample(self, cat: str, batch: int, length: int, seed: int = 0) -> np.ndarray:
        r0, r1 = self.ranges[cat]
        n = r1 - r0
        rng = np.random.default_rng(hash((cat, seed)) % (1 << 31))
        out = np.zeros((batch, length), np.int64)
        cur = rng.integers(0, n, size=batch)
        succ, probs = self.next_tokens[cat], self.next_probs[cat]
        for t in range(length):
            out[:, t] = r0 + cur
            choice = np.array([rng.choice(self.branching, p=probs[c]) for c in cur])
            cur = succ[cur, choice]
        return out.astype(np.int32)

    def stream(self, cats, n_batches: int, batch: int, length: int, seed: int = 0):
        """Round-robin over categories; yields (B, length) int32 arrays."""
        for i in range(n_batches):
            cat = cats[i % len(cats)]
            yield self.sample(cat, batch, length, seed=seed * 100003 + i)
