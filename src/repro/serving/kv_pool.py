"""Paged KV-cache page pool: fixed-size pages, per-slot block tables.

Layout
------
The decode cache for full-attention segments is one **pooled** array per
segment, ``(n_layers, P, page_size, KV, hd)``: ``P = num_pages + 1`` physical
pages shared by every lane of the decode batch.  Physical page **0 is the
null page** — the allocator never hands it out; block-table entries of ``-1``
are clamped onto it so eager speculative writes from dead/retired lanes land
somewhere harmless (null-page contents are garbage by construction and are
always masked out of attention by position validity).

Each lane owns a **block-table row** ``tbl[slot, :max_pages]`` (int32,
``-1`` = unmapped): logical token position ``t`` of that lane lives at
physical slot ``tbl[slot, t // page_size] * page_size + t % page_size``.
The block table itself is a device array inside the cache pytree (it is
read by every decode step); *ownership* — which physical pages belong to
which request, the free list, refcounts, the prefix index, watermarks —
lives host-side in ``KVPool``, which is pure Python bookkeeping and never
touches device memory.

Rollback rule
-------------
Speculative writes are eager: a block-step writes K+1 tokens at positions
``len .. len+K`` before verification.  Rejected tokens are rolled back by
**truncating the lane length only** (``commit_cache`` advances ``lengths``
by the accepted count) — no page is copied, freed, or zeroed; the stale
slots are overwritten by the next block's eager writes and are excluded
from attention by the ``pos <= qpos`` mask meanwhile.  Pages return to the
pool only on retirement / preemption (``KVPool.free``).

Adaptive speculation depth (ROADMAP: adaptive-depth contract) changes how
MANY eager writes a block makes — a lane at depth ``k`` writes ``k+1``
tokens — but not this rule: provisioning math splits into
**reservation-class** decisions (admission gating, prompt trim,
watermarks), which assume the worst-case depth ``k_max`` so a lane can
never be admitted into a pool that couldn't survive it drafting deep, and
**growth-class** decisions (per-superstep page growth), which use the
lane's live depth plus the controller's cooldown-derived rise bound.  A
lane that throttles below its provisioned depth may still eagerly write
up to the dispatch depth ``K_blk``; those surplus writes land inside the
lane's provisioned pages (or on the null page past the table) and are the
same rejected-draft garbage this section already covers — never committed,
never attended.

Prefix sharing (refcounts / COW / eviction)
-------------------------------------------
Prompt-prefix pages are content-addressed and shareable:

* **Refcounts.**  Every live page carries a refcount = the number of
  owners whose block tables map it.  ``alloc`` grants pages at refcount 1;
  ``acquire_prefix`` increments the count of each matched page while
  splicing it into the new owner's page list; ``free(owner)`` becomes a
  refcount *decrement* — a page leaves live use only when its last owner
  releases it.
* **Content index.**  ``publish_prefix(owner, tokens)`` registers the
  owner's page-aligned prompt prefix in a hash-chain index keyed on
  ``(parent_page_id, page_tokens)`` — parent 0 is the chain root, and the
  exact token tuple in the key means a hit is an exact content match (no
  hash collisions, ever).  A trailing partial page (fewer than
  ``page_size`` prompt tokens) is indexed separately per parent so it can
  seed copy-on-write.
* **Sharing is safe by construction.**  Shared pages hold strictly
  prompt-prefix tokens, committed before any speculation starts; eager
  speculative writes land only at positions >= the committed length, so a
  published FULL page is never mutated while shared.  A published partial
  page may keep growing past its indexed tokens (the donor appends
  generated tokens), but the indexed prefix slots themselves are
  append-frozen — which is why partial pages are never refcount-shared,
  only used as **copy-on-write sources**: the consumer copies the page
  device-side into a fresh exclusively-owned page before appending
  (slots past the matched prefix are garbage, overwritten by the
  consumer's own tail prefill exactly like uninitialized pool slots).
* **Eviction.**  When a published page's refcount drops to 0 it is NOT
  returned to the free list: it parks in an LRU set of evictable cached
  pages, still indexed, still hittable.  Evictable pages count as free
  for every admission/watermark decision (``can_alloc`` /
  ``available_pages``) but are reclaimed lazily: ``alloc`` evicts
  oldest-first only when the strictly-free list cannot cover the grant,
  dropping the page's index entry — and, for full pages, every descendant
  key in its subtree (child keys embed the parent's page id, which may be
  recycled; a stale child key would splice KV computed under a different
  prefix) — as it goes.  Reclaiming is pure host
  bookkeeping — page contents are never zeroed, and correctness never
  depends on them (an evicted page is unreachable from the index).

Invariants (checked by the property tests in tests/test_paged_kv.py and
tests/test_prefix_cache.py)
-------------------------------------------------------------------
* ``free_pages + cached_pages + live_pages == num_pages`` at every step,
* a live page's refcount equals the number of owners whose page list
  contains it; a page is in at most one owner's list once,
* indexed pages are always live or cached — never on the free list,
* ``alloc`` is all-or-nothing (no partial grants),
* double-``free`` and foreign-page frees raise.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax.numpy as jnp


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold `tokens` cache slots (ceil division, min 1)."""
    return max(1, -(-tokens // page_size))


def logical_to_physical(tbl, pos, page_size: int):
    """THE addressing rule: map logical token positions to physical pool
    slots through a block table.  tbl (..., MPS) int32 (-1 = unmapped);
    pos (..., L) int32 logical positions with matching leading dims.
    Returns (page, phys): the owning page id per position (-1 where
    unmapped or beyond the table) and the flat physical slot index, with
    invalid positions clamped onto the null page 0.  jnp-traceable — this
    one function is shared by the decode step, the slot splice, and the
    kernel oracle so the layout can never silently diverge."""
    mps = tbl.shape[-1]
    pidx = pos // page_size
    page = jnp.where(pidx < mps,
                     jnp.take_along_axis(tbl, jnp.clip(pidx, 0, mps - 1),
                                         axis=-1), -1)
    phys = jnp.where(page < 0, 0, page) * page_size + pos % page_size
    return page, phys


@dataclass(frozen=True)
class PrefixHit:
    """Result of ``KVPool.acquire_prefix``.

    ``pages``: shared full pages already spliced into the owner's page
    list (refcounts incremented) — ``tokens = len(pages) * page_size``
    prompt tokens are resident through them.  ``cow_page``/``cow_tokens``:
    a partially-matching cached page usable as a copy-on-write source for
    ``cow_tokens`` further tokens (0 = no partial match).  The COW source
    is NOT acquired — the caller must copy it device-side into a freshly
    allocated page before appending."""
    pages: Tuple[int, ...]
    tokens: int
    cow_page: int = 0
    cow_tokens: int = 0

    @property
    def hit_tokens(self) -> int:
        return self.tokens + self.cow_tokens


@dataclass
class KVPool:
    """Host-side free-list allocator over physical page ids ``1..num_pages``.

    Page id 0 (the null page) is reserved at construction and never
    allocated.  ``alloc`` grants the lowest-numbered free pages
    (deterministic, keeps tests reproducible); fixed-size pages mean the
    pool has no external fragmentation — the only waste is the unused tail
    of each owner's last page (see ``utilization``).  Prefix-cache state
    (refcounts, content index, LRU evictables) is documented in the module
    docstring above.
    """
    num_pages: int
    page_size: int
    _free: List[int] = field(init=False)
    _free_set: Set[int] = field(init=False)
    _owned: Dict[int, List[int]] = field(init=False, default_factory=dict)
    _ref: Dict[int, int] = field(init=False, default_factory=dict)
    # refcount-0 published pages in LRU order (dict = insertion-ordered;
    # oldest first); still indexed, still hittable, lazily reclaimed
    _cached: Dict[int, None] = field(init=False, default_factory=dict)
    # (parent_page_id, page_tokens) -> canonical page, full pages only
    _index: Dict[Tuple[int, Tuple[int, ...]], int] = field(
        init=False, default_factory=dict)
    # parent page -> {partial_tokens: page}: COW seed candidates
    _partials: Dict[int, Dict[Tuple[int, ...], int]] = field(
        init=False, default_factory=dict)
    # page -> its index key (a page carries at most one key)
    _page_key: Dict[int, tuple] = field(init=False, default_factory=dict)
    peak_used: int = field(init=False, default=0)
    alloc_calls: int = field(init=False, default=0)
    free_calls: int = field(init=False, default=0)
    failed_allocs: int = field(init=False, default=0)
    prefix_lookups: int = field(init=False, default=0)
    prefix_hits: int = field(init=False, default=0)
    prefix_misses: int = field(init=False, default=0)
    prefix_hit_tokens: int = field(init=False, default=0)
    evictions: int = field(init=False, default=0)

    def __post_init__(self):
        if self.num_pages < 1:
            raise ValueError("KVPool needs at least one allocatable page")
        if self.page_size < 1:
            raise ValueError("page_size must be positive")
        # ascending grant order: keep as a reversed stack so pop() is O(1)
        self._free = list(range(self.num_pages, 0, -1))
        self._free_set = set(self._free)

    # ---------------- capacity queries ----------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def cached_pages(self) -> int:
        """Refcount-0 published pages: evictable, lazily reclaimed."""
        return len(self._cached)

    @property
    def available_pages(self) -> int:
        """What admission math may count on: strictly free + evictable."""
        return len(self._free) + len(self._cached)

    @property
    def used_pages(self) -> int:
        """Live pages (refcount > 0); excludes evictable cached pages."""
        return self.num_pages - len(self._free) - len(self._cached)

    def pages_for(self, tokens: int) -> int:
        return pages_for(tokens, self.page_size)

    def can_alloc(self, n: int, watermark: int = 0) -> bool:
        """Would an ``alloc(n)`` succeed while keeping `watermark` pages
        available?  Evictable cached pages count as free here — they are
        reclaimable on demand — so a warm cache never blocks admission."""
        return self.available_pages - n >= watermark

    # ---------------- free-list / eviction internals ----------------

    def _push_free(self, p: int) -> None:
        self._free.append(p)
        self._free_set.add(p)

    def _pop_free(self) -> int:
        p = self._free.pop()
        self._free_set.discard(p)
        return p

    def _drop_key(self, page: int) -> None:
        key = self._page_key.pop(page, None)
        if key is None:
            return
        if key[0] == "full":
            self._index.pop(key[1], None)
            # Cascade: child keys are keyed on THIS page id.  If the id is
            # recycled and republished at another depth, a stale child key
            # would splice KV computed under a different prefix/position —
            # so the whole subtree must leave the index with its root.
            self._invalidate_children(page)
        else:
            sub = self._partials.get(key[1])
            if sub is not None:
                sub.pop(key[2], None)
                if not sub:
                    del self._partials[key[1]]

    def _invalidate_children(self, page: int) -> None:
        kids = [(k, pg) for k, pg in self._index.items() if k[0] == page]
        for k, pg in kids:
            del self._index[k]
            if self._page_key.get(pg) == ("full", k):
                del self._page_key[pg]
            self._invalidate_children(pg)
        sub = self._partials.pop(page, None)
        if sub:
            for rest, pg in sub.items():
                if self._page_key.get(pg) == ("partial", page, rest):
                    del self._page_key[pg]

    def _evict_one(self) -> int:
        """Reclaim the least-recently-used evictable page: drop its index
        entry and push it onto the free list.  Contents are NOT zeroed —
        an unindexed page is unreachable, so stale KV is as harmless as
        any other uninitialized pool slot."""
        page = next(iter(self._cached))
        del self._cached[page]
        self._drop_key(page)
        self._push_free(page)
        self.evictions += 1
        return page

    # ---------------- alloc / free ----------------

    def alloc(self, n: int, owner: int) -> Optional[List[int]]:
        """Grant `n` fresh (exclusively-owned, refcount-1) pages to `owner`
        (all-or-nothing).  Returns the page ids or None if free + evictable
        cannot satisfy the request; evictable pages are reclaimed
        oldest-first only as needed (lazy eviction)."""
        self.alloc_calls += 1
        if n < 0:
            raise ValueError("cannot allocate a negative page count")
        if n > self.available_pages:
            self.failed_allocs += 1
            return None
        while len(self._free) < n:
            self._evict_one()
        got = [self._pop_free() for _ in range(n)]
        for p in got:
            self._ref[p] = 1
        self._owned.setdefault(owner, []).extend(got)
        self.peak_used = max(self.peak_used, self.used_pages)
        return got

    def ensure(self, owner: int, pages: int) -> Optional[List[int]]:
        """Incremental provisioning: top `owner` up to `pages` total pages,
        granting only the missing delta (all-or-nothing).  Returns the NEWLY
        granted page ids ([] when the owner already holds enough) or None if
        the pool cannot satisfy the delta — the owner's existing pages are
        untouched either way.  The one growth primitive shared by decode
        page growth and chunked-prefill provisioning; growth deliberately
        ignores the ADMISSION watermark — that headroom exists precisely so
        live lanes can keep growing while admission holds back.  Shared
        prefix pages count toward the owner's total like any others."""
        need = pages - len(self._owned.get(owner, ()))
        if need <= 0:
            return []
        if need > self.available_pages:
            self.failed_allocs += 1
            return None
        return self.alloc(need, owner=owner)

    def free(self, owner: int) -> int:
        """Release ALL of `owner`'s pages (retirement or preemption):
        decrement each page's refcount; pages reaching refcount 0 return
        to the free list — unless published in the prefix index, in which
        case they park as LRU-evictable cached pages.  Returns the number
        of pages that left live use (still-shared pages are not counted)."""
        self.free_calls += 1
        pages = self._owned.pop(owner, None)
        if pages is None:
            raise KeyError(f"owner {owner} holds no pages (double free?)")
        released = 0
        for p in pages:
            if p in self._free_set:      # pragma: no cover - invariant guard
                raise RuntimeError(f"page {p} already free")
            r = self._ref[p] - 1
            if r > 0:                    # still mapped by another owner
                self._ref[p] = r
                continue
            del self._ref[p]
            if p in self._page_key:      # published: cache it, don't free it
                self._cached[p] = None   # (re)inserted at the MRU end
            else:
                self._push_free(p)
            released += 1
        return released

    def owned(self, owner: int) -> List[int]:
        return list(self._owned.get(owner, ()))

    def owners(self) -> List[int]:
        return list(self._owned)

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    # ---------------- prefix cache ----------------

    def _retain(self, page: int) -> None:
        r = self._ref.get(page)
        if r is not None:
            self._ref[page] = r + 1
        else:                            # evictable -> live again
            del self._cached[page]
            self._ref[page] = 1

    def acquire_prefix(self, owner: int, tokens: Sequence[int]) -> PrefixHit:
        """Longest-cached-prefix lookup for a new owner's prompt `tokens`:
        walk the hash chain from the root over page-aligned windows,
        splicing every matched FULL page into `owner`'s page list
        (refcount +1, logical order preserved).  The remaining tail is
        probed against the parent's partial-page entries for the longest
        common prefix — returned as a COW source, NOT acquired.  `owner`
        must hold no pages yet (admission runs before any allocation)."""
        if owner in self._owned:
            raise ValueError(f"owner {owner} already holds pages — "
                             f"acquire_prefix must run before allocation")
        self.prefix_lookups += 1
        ps = self.page_size
        toks = [int(t) for t in tokens]
        parent, matched = 0, 0
        shared: List[int] = []
        while len(toks) - matched >= ps:
            page = self._index.get(
                (parent, tuple(toks[matched:matched + ps])))
            if page is None:
                break
            self._retain(page)
            shared.append(page)
            parent = page
            matched += ps
        cow_page = cow_tokens = 0
        rest = toks[matched:]
        if rest:
            for ptoks, page in (self._partials.get(parent) or {}).items():
                j = 0
                for a, b in zip(ptoks, rest):
                    if a != b:
                        break
                    j += 1
                if j > cow_tokens:
                    cow_tokens, cow_page = j, page
        if shared:
            self._owned[owner] = shared
            self.peak_used = max(self.peak_used, self.used_pages)
        if matched + cow_tokens > 0:
            self.prefix_hits += 1
            self.prefix_hit_tokens += matched + cow_tokens
        else:
            self.prefix_misses += 1
        return PrefixHit(tuple(shared), matched, cow_page, cow_tokens)

    def publish_prefix(self, owner: int, tokens: Sequence[int]) -> int:
        """Register `owner`'s prompt prefix `tokens` in the content index
        once its prefill has fully committed.  Full pages chain through the
        CANONICAL parent (an identical page published earlier wins, so
        chains stay reachable from the root); the trailing partial page (if
        any) is indexed per parent as a COW seed.  Idempotent: pages that
        are already indexed, or whose key is already canonical elsewhere,
        are skipped.  Returns the number of newly published pages."""
        ps = self.page_size
        toks = [int(t) for t in tokens]
        pages = self._owned.get(owner, ())
        parent, new, i = 0, 0, 0
        while (i + 1) * ps <= len(toks) and i < len(pages):
            key = (parent, tuple(toks[i * ps:(i + 1) * ps]))
            canon = self._index.get(key)
            if canon is None:
                page = pages[i]
                if page in self._page_key:   # pragma: no cover - one key per
                    break                    # page; stop rather than corrupt
                self._index[key] = page
                self._page_key[page] = ("full", key)
                canon = page
                new += 1
            parent = canon
            i += 1
        else:
            rest = tuple(toks[i * ps:])
            if rest and i < len(pages):
                page = pages[i]
                sub = self._partials.setdefault(parent, {})
                if rest not in sub and page not in self._page_key:
                    sub[rest] = page
                    self._page_key[page] = ("partial", parent, rest)
                    new += 1
        return new

    # ---------------- observability ----------------

    def utilization(self, live_tokens: int = -1) -> dict:
        """Pool stats.  `live_tokens` (sum of committed lane lengths) turns
        the page-internal slack into a fragmentation ratio; pass -1 to skip."""
        used = self.used_pages
        out = {
            "num_pages": self.num_pages,
            "page_size": self.page_size,
            "used_pages": used,
            "free_pages": self.free_pages,
            "cached_pages": self.cached_pages,
            "available_pages": self.available_pages,
            "peak_used_pages": self.peak_used,
            "utilization": used / self.num_pages,
            "peak_utilization": self.peak_used / self.num_pages,
            "alloc_calls": self.alloc_calls,
            "free_calls": self.free_calls,
            "failed_allocs": self.failed_allocs,
            "prefix_lookups": self.prefix_lookups,
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_evictions": self.evictions,
            "indexed_pages": len(self._page_key),
        }
        if live_tokens >= 0:
            cap = used * self.page_size
            out["internal_fragmentation"] = (
                0.0 if cap == 0 else 1.0 - live_tokens / cap)
        return out
