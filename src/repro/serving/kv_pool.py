"""Paged KV-cache page pool: fixed-size pages, per-slot block tables.

Layout
------
The decode cache for full-attention segments is one **pooled** array per
segment, ``(n_layers, P, page_size, KV, hd)``: ``P = num_pages + 1`` physical
pages shared by every lane of the decode batch.  Physical page **0 is the
null page** — the allocator never hands it out; block-table entries of ``-1``
are clamped onto it so eager speculative writes from dead/retired lanes land
somewhere harmless (null-page contents are garbage by construction and are
always masked out of attention by position validity).

Each lane owns a **block-table row** ``tbl[slot, :max_pages]`` (int32,
``-1`` = unmapped): logical token position ``t`` of that lane lives at
physical slot ``tbl[slot, t // page_size] * page_size + t % page_size``.
The block table itself is a device array inside the cache pytree (it is
read by every decode step); *ownership* — which physical pages belong to
which request, the free list, watermarks — lives host-side in ``KVPool``,
which is pure Python bookkeeping and never touches device memory.

Rollback rule
-------------
Speculative writes are eager: a block-step writes K+1 tokens at positions
``len .. len+K`` before verification.  Rejected tokens are rolled back by
**truncating the lane length only** (``commit_cache`` advances ``lengths``
by the accepted count) — no page is copied, freed, or zeroed; the stale
slots are overwritten by the next block's eager writes and are excluded
from attention by the ``pos <= qpos`` mask meanwhile.  Pages return to the
free list only on retirement / preemption (``KVPool.free``).

Adaptive speculation depth (ROADMAP: adaptive-depth contract) changes how
MANY eager writes a block makes — a lane at depth ``k`` writes ``k+1``
tokens — but not this rule: provisioning math splits into
**reservation-class** decisions (admission gating, prompt trim,
watermarks), which assume the worst-case depth ``k_max`` so a lane can
never be admitted into a pool that couldn't survive it drafting deep, and
**growth-class** decisions (per-superstep page growth), which use the
lane's live depth plus the controller's cooldown-derived rise bound.  A
lane that throttles below its provisioned depth may still eagerly write
up to the dispatch depth ``K_blk``; those surplus writes land inside the
lane's provisioned pages (or on the null page past the table) and are the
same rejected-draft garbage this section already covers — never committed,
never attended.

Invariants (checked by the property test in tests/test_paged_kv.py)
-------------------------------------------------------------------
* a physical page is owned by at most one owner at a time,
* ``free_pages + pages_in_use == num_pages`` at every step,
* ``alloc`` is all-or-nothing (no partial grants),
* double-``free`` and foreign-page frees raise.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax.numpy as jnp


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold `tokens` cache slots (ceil division, min 1)."""
    return max(1, -(-tokens // page_size))


def logical_to_physical(tbl, pos, page_size: int):
    """THE addressing rule: map logical token positions to physical pool
    slots through a block table.  tbl (..., MPS) int32 (-1 = unmapped);
    pos (..., L) int32 logical positions with matching leading dims.
    Returns (page, phys): the owning page id per position (-1 where
    unmapped or beyond the table) and the flat physical slot index, with
    invalid positions clamped onto the null page 0.  jnp-traceable — this
    one function is shared by the decode step, the slot splice, and the
    kernel oracle so the layout can never silently diverge."""
    mps = tbl.shape[-1]
    pidx = pos // page_size
    page = jnp.where(pidx < mps,
                     jnp.take_along_axis(tbl, jnp.clip(pidx, 0, mps - 1),
                                         axis=-1), -1)
    phys = jnp.where(page < 0, 0, page) * page_size + pos % page_size
    return page, phys


@dataclass
class KVPool:
    """Host-side free-list allocator over physical page ids ``1..num_pages``.

    Page id 0 (the null page) is reserved at construction and never
    allocated.  ``alloc`` grants the lowest-numbered free pages
    (deterministic, keeps tests reproducible); fixed-size pages mean the
    pool has no external fragmentation — the only waste is the unused tail
    of each owner's last page (see ``utilization``).
    """
    num_pages: int
    page_size: int
    _free: List[int] = field(init=False)
    _owned: Dict[int, List[int]] = field(init=False, default_factory=dict)
    peak_used: int = field(init=False, default=0)
    alloc_calls: int = field(init=False, default=0)
    free_calls: int = field(init=False, default=0)
    failed_allocs: int = field(init=False, default=0)

    def __post_init__(self):
        if self.num_pages < 1:
            raise ValueError("KVPool needs at least one allocatable page")
        if self.page_size < 1:
            raise ValueError("page_size must be positive")
        # ascending grant order: keep as a reversed stack so pop() is O(1)
        self._free = list(range(self.num_pages, 0, -1))

    # ---------------- capacity queries ----------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def pages_for(self, tokens: int) -> int:
        return pages_for(tokens, self.page_size)

    def can_alloc(self, n: int, watermark: int = 0) -> bool:
        """Would an ``alloc(n)`` succeed while keeping `watermark` pages free?"""
        return self.free_pages - n >= watermark

    # ---------------- alloc / free ----------------

    def alloc(self, n: int, owner: int) -> Optional[List[int]]:
        """Grant `n` pages to `owner` (all-or-nothing).  Returns the page ids
        (ascending) or None if the pool cannot satisfy the request."""
        self.alloc_calls += 1
        if n < 0:
            raise ValueError("cannot allocate a negative page count")
        if n > len(self._free):
            self.failed_allocs += 1
            return None
        got = [self._free.pop() for _ in range(n)]
        self._owned.setdefault(owner, []).extend(got)
        self.peak_used = max(self.peak_used, self.used_pages)
        return got

    def ensure(self, owner: int, pages: int) -> Optional[List[int]]:
        """Incremental provisioning: top `owner` up to `pages` total pages,
        granting only the missing delta (all-or-nothing).  Returns the NEWLY
        granted page ids ([] when the owner already holds enough) or None if
        the pool cannot satisfy the delta — the owner's existing pages are
        untouched either way.  The one growth primitive shared by decode
        page growth and chunked-prefill provisioning; growth deliberately
        ignores the ADMISSION watermark — that headroom exists precisely so
        live lanes can keep growing while admission holds back."""
        need = pages - len(self._owned.get(owner, ()))
        if need <= 0:
            return []
        if need > len(self._free):
            self.failed_allocs += 1
            return None
        return self.alloc(need, owner=owner)

    def free(self, owner: int) -> int:
        """Return ALL of `owner`'s pages to the free list (retirement or
        preemption).  Returns the number of pages released."""
        self.free_calls += 1
        pages = self._owned.pop(owner, None)
        if pages is None:
            raise KeyError(f"owner {owner} holds no pages (double free?)")
        for p in pages:
            if p in self._free:          # pragma: no cover - invariant guard
                raise RuntimeError(f"page {p} already free")
        self._free.extend(sorted(pages, reverse=True))
        return len(pages)

    def owned(self, owner: int) -> List[int]:
        return list(self._owned.get(owner, ()))

    def owners(self) -> List[int]:
        return list(self._owned)

    # ---------------- observability ----------------

    def utilization(self, live_tokens: int = -1) -> dict:
        """Pool stats.  `live_tokens` (sum of committed lane lengths) turns
        the page-internal slack into a fragmentation ratio; pass -1 to skip."""
        used = self.used_pages
        out = {
            "num_pages": self.num_pages,
            "page_size": self.page_size,
            "used_pages": used,
            "free_pages": self.free_pages,
            "peak_used_pages": self.peak_used,
            "utilization": used / self.num_pages,
            "peak_utilization": self.peak_used / self.num_pages,
            "alloc_calls": self.alloc_calls,
            "free_calls": self.free_calls,
            "failed_allocs": self.failed_allocs,
        }
        if live_tokens >= 0:
            cap = used * self.page_size
            out["internal_fragmentation"] = (
                0.0 if cap == 0 else 1.0 - live_tokens / cap)
        return out
