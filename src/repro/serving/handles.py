"""Async request surface for the serving engine: handles + tenant queue.

The engine's historical ``submit(Request) -> None`` gave callers nothing
back: no way to stream tokens, no way to cancel, no identity beyond the
uid they invented.  This module is the redesigned surface:

* ``RequestHandle`` — returned by ``ServingEngine.submit_request``.  It
  carries the request's identity (uid / tenant / priority), its lifecycle
  timestamps (submit / admit / prefill-done / first-token / done, all on
  the engine's injected clock), and a thread-safe incremental token
  stream: the engine ``feed``s the authoritative generated-token total at
  each superstep harvest, and any number of consumer threads iterate
  ``deltas()`` (incremental chunks), block on ``result()``, or call
  ``cancel()``.  Cancellation is a flag the engine honours at the next
  superstep boundary (the only place lanes may be retired — see the
  superstep contract in engine.py); the handle then finishes with
  ``outcome == "cancelled"`` and whatever tokens were committed first.

* ``TenantQueue`` — the continuous scheduler's admission queue, upgraded
  from a plain FIFO to per-tenant start-time-fair queuing: each tenant
  has a virtual-time tag advanced by ``1/weight`` per dequeue, the
  next admission comes from the eligible tenant with the smallest tag
  (idle tenants re-enter at the current virtual time, so parking never
  accrues credit), and within a tenant entries order by (priority desc,
  arrival).  Preemption replays bypass fairness via ``push_front`` —
  they already won admission once and re-queue at the global front (the
  no-livelock argument in engine._preempt depends on this).  A bounded
  queue (``max_queue``) rejects with ``QueueFull`` at submit time
  instead of queuing without bound — backpressure is explicit.

Everything here is pure host-side bookkeeping: no jax, no device work.
The lock scope is the submit/harvest thread boundary the HTTP front-end
relies on (serving/http.py): ``push``/``QueueFull`` from any thread,
``peek``/``take`` only from the engine thread.
"""
from __future__ import annotations

import heapq
import threading
import time
from collections import deque
from typing import Callable, Dict, Iterator, List, Optional


class QueueFull(RuntimeError):
    """Admission queue at ``max_queue``: the request was REJECTED, not
    queued.  Callers (e.g. the HTTP layer's 429) decide retry policy."""


class RequestHandle:
    """Caller-facing view of one in-flight request.

    Engine-side entry points (called only from the engine thread):
    ``feed`` / ``finish`` / ``abort``.  Everything else is safe from any
    thread.  Token delivery is monotone: ``feed`` receives the
    authoritative generated-token TOTAL (the engine's ``_Slot.gen``,
    which survives preemption/replay), so a replayed lane can never
    un-deliver or re-deliver tokens.
    """

    def __init__(self, uid: int, tenant: str = "default", priority: int = 0,
                 clock: Callable[[], float] = time.monotonic):
        self.uid = uid
        self.tenant = tenant
        self.priority = priority
        self._clock = clock
        self._cond = threading.Condition()
        self._tokens: List[int] = []
        self._completion = None
        self.outcome: Optional[str] = None   # completed|cancelled|rejected|error
        self.error: Optional[str] = None
        self._cancel = False
        # lifecycle timestamps on the ENGINE's clock (None until reached)
        self.t_submit: Optional[float] = None
        self.t_admit: Optional[float] = None
        self.t_prefill_done: Optional[float] = None
        self.t_first_token: Optional[float] = None
        self.t_done: Optional[float] = None

    # -- engine side ---------------------------------------------------

    def feed(self, total_gen) -> int:
        """Publish the authoritative generated-token total; returns how
        many NEW tokens this call delivered.  Idempotent for replays."""
        with self._cond:
            n = len(self._tokens)
            if len(total_gen) > n:
                self._tokens.extend(int(t) for t in total_gen[n:])
                if self.t_first_token is None:
                    self.t_first_token = self._clock()
                self._cond.notify_all()
            return len(self._tokens) - n

    def finish(self, completion, outcome: str = "completed",
               t_done: Optional[float] = None) -> None:
        """Terminal transition (engine thread): record the completion (or
        the partial one for a cancel), stamp ``t_done``, wake waiters."""
        with self._cond:
            if self.outcome is not None:
                return
            if completion is not None:
                gen = completion.gen_tokens
                n = len(self._tokens)
                if len(gen) > n:                 # final flush, same stream
                    self._tokens.extend(int(t) for t in gen[n:])
                    if self.t_first_token is None and self._tokens:
                        self.t_first_token = self._clock()
            self._completion = completion
            self.outcome = outcome
            self.t_done = t_done if t_done is not None else self._clock()
            self._cond.notify_all()

    def abort(self, reason: str) -> None:
        """Engine died / shut down without serving this request: unblock
        every waiter with ``outcome == "error"``."""
        with self._cond:
            if self.outcome is not None:
                return
            self.error = reason
            self.outcome = "error"
            self.t_done = self._clock()
            self._cond.notify_all()

    # -- caller side ---------------------------------------------------

    @property
    def finished(self) -> bool:
        return self.outcome is not None

    @property
    def cancel_requested(self) -> bool:
        return self._cancel

    @property
    def status(self) -> str:
        if self.outcome is not None:
            return "done"
        return "queued" if self.t_admit is None else "running"

    def cancel(self) -> bool:
        """Request cancellation; honoured at the next superstep boundary.
        Returns False when the request already finished (nothing to do)."""
        with self._cond:
            if self.outcome is not None:
                return False
            self._cancel = True
            return True

    def tokens(self) -> List[int]:
        with self._cond:
            return list(self._tokens)

    def deltas(self, timeout: Optional[float] = None) -> Iterator[List[int]]:
        """Yield incremental generated-token chunks as the engine harvests
        them (one chunk per superstep boundary that committed tokens for
        this lane), ending when the request finishes.  ``timeout`` bounds
        the wait for EACH chunk; expiry raises ``TimeoutError``."""
        pos = 0
        while True:
            with self._cond:
                while len(self._tokens) == pos and self.outcome is None:
                    if not self._cond.wait(timeout):
                        raise TimeoutError(
                            f"request {self.uid}: no tokens within "
                            f"{timeout}s")
                chunk = self._tokens[pos:]
                pos = len(self._tokens)
                done = self.outcome is not None
            if chunk:
                yield chunk
            if done:
                if self.outcome == "error":
                    raise RuntimeError(
                        f"request {self.uid} aborted: {self.error}")
                return

    def result(self, timeout: Optional[float] = None):
        """Block until the request finishes; returns the ``Completion``
        (partial for ``outcome == "cancelled"``)."""
        with self._cond:
            if not self._cond.wait_for(lambda: self.outcome is not None,
                                       timeout):
                raise TimeoutError(f"request {self.uid}: not finished "
                                   f"within {timeout}s")
            if self.outcome == "error":
                raise RuntimeError(f"request {self.uid} aborted: "
                                   f"{self.error}")
            return self._completion

    def timings(self) -> Dict[str, Optional[float]]:
        """The latency split ``Completion.latency_s`` folded into: queue
        wait (submit -> admit), prefill (admit -> prefill done), decode
        (prefill done -> done), plus TTFT and end-to-end.  Entries are
        None until the corresponding lifecycle edge happened."""
        def span(a, b):
            return None if a is None or b is None else b - a

        return {
            "queue_wait_s": span(self.t_submit, self.t_admit),
            "prefill_s": span(self.t_admit, self.t_prefill_done),
            "decode_s": span(self.t_prefill_done, self.t_done),
            "ttft_s": span(self.t_submit, self.t_first_token),
            "e2e_s": span(self.t_submit, self.t_done),
        }


class TenantQueue:
    """Per-tenant weighted start-time-fair admission queue.

    * ``push`` (any thread): enqueue under the request's tenant; raises
      ``QueueFull`` once ``max_queue`` entries wait (0 = unbounded).
    * ``peek``/``take`` (engine thread): ``peek`` returns the request the
      fair scheduler would admit next WITHOUT removing it (admission may
      be watermark-blocked and retried next tick); ``take(req)`` removes
      exactly that request and charges its tenant's virtual-time tag.
    * ``push_front``: preemption replay — global front of the queue,
      bypassing both fairness and the bound (the request was already
      admitted once; dropping it would lose committed work).
    * ``drop(uids)``: remove cancelled entries wherever they sit.

    Fairness: tenant ``t`` holds a virtual finish tag ``F[t]``; a dequeue
    charges ``F[t] = max(F[t], V) + 1/weight[t]`` and advances the global
    virtual time ``V`` to the start tag.  ``max(F[t], V)`` re-enters idle
    tenants at the current virtual time, so a parked tenant resumes
    sharing from NOW rather than burning accumulated credit.  Within a
    tenant: (priority desc, arrival order).
    """

    def __init__(self, max_queue: int = 0,
                 weights: Optional[Dict[str, float]] = None):
        self.max_queue = int(max_queue)
        self._weights = dict(weights or {})
        self._heaps: Dict[str, list] = {}
        self._tags: Dict[str, float] = {}
        self._v = 0.0
        self._front: deque = deque()
        self._entry: Dict[int, tuple] = {}     # uid -> (tenant, seq)
        self._dead: set = set()                # seqs removed out of order
        self._seq = 0
        self._n = 0
        self._lock = threading.Lock()

    def _weight(self, tenant: str) -> float:
        w = float(self._weights.get(tenant, 1.0))
        return w if w > 0 else 1.0

    def push(self, req) -> None:
        with self._lock:
            if self.max_queue and self._n >= self.max_queue:
                raise QueueFull(
                    f"admission queue full ({self._n}/{self.max_queue}); "
                    f"request uid={req.uid} tenant={req.tenant!r} rejected")
            self._seq += 1
            tenant = getattr(req, "tenant", "default")
            heapq.heappush(self._heaps.setdefault(tenant, []),
                           (-int(getattr(req, "priority", 0)), self._seq,
                            req))
            self._entry[req.uid] = (tenant, self._seq)
            self._n += 1

    def push_front(self, req) -> None:
        with self._lock:
            self._front.appendleft(req)
            self._n += 1

    def _prune(self, tenant: str) -> None:
        h = self._heaps.get(tenant)
        while h and h[0][1] in self._dead:
            self._dead.discard(heapq.heappop(h)[1])

    def _select(self) -> Optional[str]:
        best = None
        for t in sorted(self._heaps):          # deterministic tiebreak
            self._prune(t)
            if not self._heaps[t]:
                continue
            s = max(self._tags.get(t, 0.0), self._v)
            if best is None or s < best[0]:
                best = (s, t)
        return None if best is None else best[1]

    def peek(self):
        """The request ``take`` would admit next (None when empty)."""
        with self._lock:
            if self._front:
                return self._front[0]
            t = self._select()
            return None if t is None else self._heaps[t][0][2]

    def take(self, req) -> None:
        """Remove exactly `req` (normally the last ``peek`` result) and,
        if it came through the fair queue, charge its tenant's tag."""
        with self._lock:
            for i, r in enumerate(self._front):
                if r.uid == req.uid:
                    del self._front[i]
                    self._n -= 1
                    return
            tenant, seq = self._entry.pop(req.uid)
            self._prune(tenant)
            h = self._heaps.get(tenant)
            if h and h[0][1] == seq:
                heapq.heappop(h)
            else:                              # displaced head: lazy-delete
                self._dead.add(seq)
            s = max(self._tags.get(tenant, 0.0), self._v)
            self._v = s
            self._tags[tenant] = s + 1.0 / self._weight(tenant)
            self._n -= 1

    def drop(self, uids) -> list:
        """Remove every queued entry whose uid is in `uids` (cancelled
        requests); returns the removed Request objects.  No tenant charge
        — cancelled-before-admission work consumed nothing."""
        out = []
        with self._lock:
            keep = deque()
            while self._front:
                r = self._front.popleft()
                (out if r.uid in uids else keep).append(r)
            self._front = keep
            for uid in list(uids):
                ent = self._entry.get(uid)
                if ent is None:
                    continue
                tenant, seq = self._entry.pop(uid)
                self._prune(tenant)
                h = self._heaps.get(tenant)
                if h and h[0][1] == seq:
                    out.append(heapq.heappop(h)[2])
                else:
                    for k, (_, sq, r) in enumerate(h or ()):
                        if sq == seq:
                            out.append(r)
                            h[k] = h[-1]
                            h.pop()
                            heapq.heapify(h)
                            break
            self._n -= len(out)
        return out

    def __len__(self) -> int:
        return self._n

    def __bool__(self) -> bool:
        return self._n > 0
