"""OpenAI-compatible HTTP front-end over the async request API.

Two pieces, both stdlib-only (the CI image has no web framework):

* ``EngineDriver`` — runs the ServingEngine on ONE dedicated thread and
  is the engine's only entry point from then on.  HTTP handler threads
  never touch engine state: they post closures via ``call(fn)`` (executed
  on the engine thread between ticks, result/exception marshalled back)
  and consume ``RequestHandle``s, which are thread-safe by design.  The
  split matches the engine's concurrency contract: all scheduling state
  is single-threaded; only the handle surface (deltas/result/cancel) and
  the tenant queue's ``push`` are cross-thread.

* ``ApiHandler`` / ``make_server`` — the wire protocol:

  ===========================  =============================================
  route                        behaviour
  ===========================  =============================================
  POST /v1/completions         OpenAI completions; ``"stream": true`` sends
                               SSE chunks (one per superstep harvest that
                               committed tokens), ``data: [DONE]`` terminator
  GET  /v1/models              the one served model
  GET  /metrics                Prometheus text (engine-thread snapshot)
  GET  /healthz                liveness + queue/lane gauges
  ===========================  =============================================

  Prompts are token-id lists (this repo serves a synthetic vocab; there
  is no tokenizer): ``"prompt": [3, 17, 99]`` or ``"3 17 99"``.  Chunk
  ``text`` is the space-joined ids (``"12 7 "``) so SSE concatenation
  round-trips to the exact stream; ``token_ids`` carries the raw ints.
  ``"user"`` maps to the engine's tenant, ``"priority"`` to within-tenant
  priority.  A full admission queue (engine ``max_queue``) surfaces as
  HTTP 429; a client disconnect mid-stream cancels the request at the
  next superstep boundary (``handle.cancel()``).

Responses are HTTP/1.0 close-delimited (no chunked framing needed for
SSE).  The server uses non-daemon handler threads so ``server_close()``
joins in-flight streams — the graceful-shutdown path in
``launch/api_server.py`` relies on that ordering.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Optional

import numpy as np

from repro.serving.engine import Request, ServingEngine
from repro.serving.handles import QueueFull, RequestHandle


class _Future:
    """Minimal one-shot result slot for cross-thread calls."""

    def __init__(self):
        self._ev = threading.Event()
        self._result = None
        self._exc: Optional[BaseException] = None

    def set_result(self, r):
        self._result = r
        self._ev.set()

    def set_exception(self, e: BaseException):
        self._exc = e
        self._ev.set()

    def get(self, timeout: Optional[float] = None):
        if not self._ev.wait(timeout):
            raise TimeoutError("engine call timed out")
        if self._exc is not None:
            raise self._exc
        return self._result


class EngineDriver:
    """Single-threaded engine executor with a cross-thread call inbox.

    The loop: drain posted closures, then step the engine while it is
    busy; when idle (or paused) park on an event with a short timeout so
    a fresh submission starts decoding within ``poll_s``.  ``stop``
    optionally drains in-flight work first — the graceful-shutdown
    contract.  If the engine thread dies, every queued call and every
    live handle is failed loudly instead of hanging its waiters.
    """

    def __init__(self, engine: ServingEngine, poll_s: float = 0.02):
        self.engine = engine
        self.poll_s = poll_s
        self._uids = itertools.count(1)
        self._inbox: list = []
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._stopping = False
        self._paused = False
        self.crashed: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._loop,
                                        name="engine-driver", daemon=True)

    # -- lifecycle -----------------------------------------------------

    def start(self) -> "EngineDriver":
        self._thread.start()
        return self

    def stop(self, drain: bool = True, timeout: float = 300.0) -> None:
        """Stop the engine thread; ``drain=True`` first finishes every
        admitted/queued request (cancelled ones retire at their next
        boundary).  Un-drained pending handles are aborted."""
        if drain and self._thread.is_alive():
            self._paused = False
            deadline = time.monotonic() + timeout
            while time.monotonic() < deadline:
                try:
                    if not self.call(lambda: self.engine.busy, timeout=30.0):
                        break
                except (RuntimeError, TimeoutError):
                    break
                time.sleep(0.01)
        self._stopping = True
        self._wake.set()
        self._thread.join(timeout=30.0)
        with self._lock:                    # fail calls posted too late
            batch, self._inbox = self._inbox, []
        for _, fut in batch:
            fut.set_exception(RuntimeError("engine driver stopped"))
        if not drain or self.crashed is not None:
            self.engine.abort_pending("engine driver stopped")

    def pause(self) -> None:
        """Freeze stepping (calls still execute) — lets tests fill the
        admission queue deterministically to exercise QueueFull/429."""
        self._paused = True
        self._wake.set()

    def resume(self) -> None:
        self._paused = False
        self._wake.set()

    # -- cross-thread surface -------------------------------------------

    def call(self, fn: Callable, timeout: float = 120.0):
        """Run ``fn()`` on the engine thread; return its result (or raise
        its exception) here."""
        if self.crashed is not None:
            raise RuntimeError(f"engine thread crashed: {self.crashed!r}")
        if not self._thread.is_alive():
            raise RuntimeError("engine driver is not running")
        fut = _Future()
        with self._lock:
            self._inbox.append((fn, fut))
        self._wake.set()
        return fut.get(timeout)

    def next_uid(self) -> int:
        return next(self._uids)

    def submit(self, req: Request, timeout: float = 120.0) -> RequestHandle:
        return self.call(lambda: self.engine.submit_request(req), timeout)

    # -- engine thread --------------------------------------------------

    def _drain_inbox(self) -> None:
        with self._lock:
            batch, self._inbox = self._inbox, []
        for fn, fut in batch:
            try:
                fut.set_result(fn())
            except BaseException as e:          # marshalled to the caller
                fut.set_exception(e)

    def _loop(self) -> None:
        try:
            while not self._stopping:
                self._drain_inbox()
                if self._paused or not self.engine.busy:
                    self._wake.wait(self.poll_s)
                    self._wake.clear()
                    continue
                self.engine.step()
            self._drain_inbox()                  # stop(): late busy-probes
        except BaseException as e:
            self.crashed = e
            with self._lock:
                batch, self._inbox = self._inbox, []
            for _, fut in batch:
                fut.set_exception(
                    RuntimeError(f"engine thread crashed: {e!r}"))
            self.engine.abort_pending(f"engine thread crashed: {e!r}")


# ---------------------------------------------------------------------------
# wire protocol
# ---------------------------------------------------------------------------

def _parse_prompt(raw) -> np.ndarray:
    if isinstance(raw, str):
        raw = [int(t) for t in raw.split()]
    if not isinstance(raw, list) or not raw or \
            not all(isinstance(t, int) and not isinstance(t, bool)
                    for t in raw):
        raise ValueError("prompt must be a non-empty list of token ids "
                         "(or a whitespace-separated id string)")
    return np.asarray(raw, np.int32)


def _chunk_payload(rid: str, model: str, tokens,
                   finish_reason: Optional[str]) -> dict:
    return {
        "id": rid, "object": "text_completion", "model": model,
        "choices": [{
            "index": 0,
            "text": "".join(f"{int(t)} " for t in tokens),
            "token_ids": [int(t) for t in tokens],
            "finish_reason": finish_reason,
        }],
    }


class ApiHandler(BaseHTTPRequestHandler):
    # HTTP/1.0: bodies are close-delimited, so SSE needs no chunked framing
    protocol_version = "HTTP/1.0"
    server_version = "dvi-serving"

    def log_message(self, fmt, *args):          # route access logs away
        if getattr(self.server, "verbose", False):
            super().log_message(fmt, *args)

    # -- helpers --------------------------------------------------------

    def _json(self, code: int, obj: dict) -> None:
        body = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _error(self, code: int, msg: str, kind: str = "invalid_request_error"):
        self._json(code, {"error": {"message": msg, "type": kind}})

    # -- routes ---------------------------------------------------------

    def do_GET(self):
        driver: EngineDriver = self.server.driver
        if self.path == "/healthz":
            if driver.crashed is not None:
                self._json(503, {"status": "crashed",
                                 "error": repr(driver.crashed)})
                return
            self._json(200, {"status": "ok",
                             "model": self.server.model_id})
        elif self.path == "/metrics":
            try:
                text = driver.call(
                    lambda: driver.engine.render_prometheus())
            except (RuntimeError, TimeoutError) as e:
                self._error(503, f"metrics unavailable: {e}", "server_error")
                return
            body = text.encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)
        elif self.path == "/v1/models":
            self._json(200, {"object": "list", "data": [{
                "id": self.server.model_id, "object": "model",
                "owned_by": "dvi"}]})
        else:
            self._error(404, f"no route {self.path!r}")

    def do_POST(self):
        if self.path != "/v1/completions":
            self._error(404, f"no route {self.path!r}")
            return
        driver: EngineDriver = self.server.driver
        try:
            n = int(self.headers.get("Content-Length", 0))
            body = json.loads(self.rfile.read(n) or b"{}")
            prompt = _parse_prompt(body.get("prompt"))
        except (ValueError, json.JSONDecodeError) as e:
            self._error(400, str(e))
            return
        max_new = int(body.get("max_tokens", self.server.default_max_new))
        stream = bool(body.get("stream", False))
        uid = driver.next_uid()
        req = Request(uid=uid, prompt=prompt, max_new=max_new,
                      tenant=str(body.get("user", "default")),
                      priority=int(body.get("priority", 0)))
        try:
            handle = driver.submit(req)
        except QueueFull as e:
            self._error(429, str(e), "rate_limit_exceeded")
            return
        except (RuntimeError, TimeoutError) as e:
            self._error(503, str(e), "server_error")
            return
        rid = f"cmpl-{uid}"
        model = self.server.model_id
        if stream:
            self._stream(rid, model, handle)
        else:
            self._complete_blocking(rid, model, handle)

    def _finish_reason(self, handle: RequestHandle, tokens) -> str:
        if handle.outcome == "cancelled":
            return "cancelled"
        eos = self.server.driver.engine.eos_id
        return "stop" if len(tokens) and int(tokens[-1]) == eos else "length"

    def _complete_blocking(self, rid, model, handle: RequestHandle):
        try:
            comp = handle.result(timeout=self.server.request_timeout_s)
        except (TimeoutError, RuntimeError) as e:
            handle.cancel()
            self._error(503, str(e), "server_error")
            return
        toks = handle.tokens()
        payload = _chunk_payload(rid, model, toks,
                                 self._finish_reason(handle, toks))
        payload["usage"] = {
            "prompt_tokens": int(len(comp.tokens) - len(comp.gen_tokens))
            if comp is not None else 0,
            "completion_tokens": len(toks),
            "total_tokens": int(len(comp.tokens)) if comp is not None
            else len(toks)}
        payload["timings"] = handle.timings()
        self._json(200, payload)

    def _stream(self, rid, model, handle: RequestHandle):
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.end_headers()

        def send(obj) -> None:
            self.wfile.write(f"data: {json.dumps(obj)}\n\n".encode())
            self.wfile.flush()

        sent = []
        try:
            for chunk in handle.deltas(
                    timeout=self.server.request_timeout_s):
                sent.extend(chunk)
                send(_chunk_payload(rid, model, chunk, None))
            send(_chunk_payload(rid, model, [],
                                self._finish_reason(handle, sent)))
            self.wfile.write(b"data: [DONE]\n\n")
            self.wfile.flush()
        except (BrokenPipeError, ConnectionResetError):
            # client went away: stop generating at the next boundary
            handle.cancel()
        except (TimeoutError, RuntimeError) as e:
            handle.cancel()
            try:
                send({"error": {"message": str(e), "type": "server_error"}})
            except OSError:
                pass


class ApiServer(ThreadingHTTPServer):
    """ThreadingHTTPServer wired to an EngineDriver.  Handler threads are
    NON-daemon so ``server_close()`` joins in-flight request streams —
    shutdown order (api_server.py): ``shutdown()`` stops accepting,
    ``server_close()`` drains handlers (engine still stepping), then
    ``driver.stop(drain=True)``."""
    daemon_threads = False
    allow_reuse_address = True

    def __init__(self, addr, driver: EngineDriver, model_id: str,
                 default_max_new: int = 64, request_timeout_s: float = 300.0,
                 verbose: bool = False):
        super().__init__(addr, ApiHandler)
        self.driver = driver
        self.model_id = model_id
        self.default_max_new = default_max_new
        self.request_timeout_s = request_timeout_s
        self.verbose = verbose


def make_server(host: str, port: int, engine: ServingEngine, model_id: str,
                default_max_new: int = 64,
                request_timeout_s: float = 300.0) -> ApiServer:
    """Start the engine driver and bind the API server (caller runs
    ``serve_forever``)."""
    driver = EngineDriver(engine).start()
    return ApiServer((host, port), driver, model_id,
                     default_max_new=default_max_new,
                     request_timeout_s=request_timeout_s)
