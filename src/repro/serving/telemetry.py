"""Unified serving + training telemetry: metrics registry and lifecycle tracer.

This module is the **normative schema reference** for the repo's
observability layer.  It provides three independent pieces that the
serving engine wires together:

1. A **metrics registry** (`MetricsRegistry`) of counters, gauges, and
   histograms with snapshot/delta semantics and Prometheus text-format
   rendering (`render_prometheus` / `parse_prometheus_text` round-trip).
   Histograms are log-bucketed for durations (`log_buckets`) and
   exact-integer-bucketed for discrete quantities (per-block accepted
   drafts, per-block speculation depth), so bucket counts reconcile
   EXACTLY with the flat counters they shadow.

2. A **lifecycle tracer** (`Tracer`) that emits Chrome-trace / Perfetto
   JSON ("trace event format", ``{"traceEvents": [...]}``).  Tracks map
   to decode lanes plus three synthetic tracks (queue / engine / train);
   spans are complete ``ph="X"`` events and point events are ``ph="i"``
   instants.  Open the output at https://ui.perfetto.dev (or
   chrome://tracing) — see ROADMAP "Observability".

3. `ServingTelemetry`: the canonical **metric declarations** for the
   serving engine — the single place the engine's legacy ``stats`` key
   set is defined (`StatsView` is a dict-compatible facade over the
   registry, so ``engine.stats["blocks"] += n`` keeps working while the
   registry is the source of truth, and ``reset_stats`` can never drift
   from the declaration table).

Metric namespace
----------------

``dvi_serving_*`` — scheduler / decode-path metrics:

=============================================  =========  =====================================
name                                           type       meaning
=============================================  =========  =====================================
dvi_serving_requests_total                     counter    completed requests
dvi_serving_blocks_total                       counter    per-live-lane speculative blocks
dvi_serving_steps_total                        counter    scheduler iterations (batch steps)
dvi_serving_committed_tokens_total             counter    tokens committed by the verifier
dvi_serving_accepted_drafts_total              counter    drafted tokens accepted
dvi_serving_drafted_tokens_total               counter    drafted tokens proposed
dvi_serving_preemptions_total                  counter    paged-pool preempt-or-queue events
dvi_serving_host_syncs_total                   counter    device->host syncs on the hot path
dvi_serving_sync_wait_seconds_total            counter    host time blocked on the device
dvi_serving_dispatches_total                   counter    superstep dispatches
dvi_serving_prefill_chunks_total               counter    batched prefill chunk steps
dvi_serving_prefill_tokens_total               counter    prompt tokens prefilled via chunks
dvi_serving_kv_watermark_hits_total            counter    admissions blocked on pool headroom
dvi_serving_prefix_lookups_total               counter    prefix-cache admission lookups
dvi_serving_prefix_hits_total                  counter    lookups matching >=1 cached token
dvi_serving_prefix_misses_total                counter    lookups matching nothing
                                                          (hits + misses == lookups, EXACT)
dvi_serving_prefix_hit_tokens_total            counter    prompt tokens skipped via cached
                                                          prefixes (>= hits when hits > 0)
dvi_serving_prefix_cow_copies_total            counter    copy-on-write page copies performed
                                                          at warm admission (<= hits)
dvi_serving_prefix_evictions_total             counter    cached pages lazily reclaimed (LRU)
dvi_serving_submitted_total                    counter    requests submitted (incl. rejected)
dvi_serving_cancelled_total                    counter    requests cancelled (any stage)
dvi_serving_rejected_total                     counter    submissions rejected (QueueFull)
dvi_serving_requests_by_tenant                 counter    per-tenant submissions, label
                                                          tenant="..." (values sum to
                                                          submitted_total, EXACT)
dvi_serving_peak_live_slots                    gauge      high-water concurrent lanes
dvi_serving_live_slots                         gauge      currently occupied lanes
dvi_serving_queue_depth                        gauge      requests waiting for a lane
dvi_serving_max_tick_prefill_tokens            gauge      largest single-tick prefill budget
dvi_serving_kv_used_pages                      gauge      pool pages live (refcount > 0)
dvi_serving_kv_free_pages                      gauge      pool pages free + evictable cached
dvi_serving_kv_cached_pages                    gauge      evictable prefix-cached pages
dvi_serving_depth_mean                         gauge      mean live-lane speculation depth
dvi_serving_request_latency_seconds            histogram  submit -> completion (log buckets)
dvi_serving_queue_wait_seconds                 histogram  submit -> first admission
dvi_serving_ttft_seconds                       histogram  submit -> first committed token
dvi_serving_tick_seconds                       histogram  engine tick wall time (log buckets)
dvi_serving_sync_wait_seconds                  histogram  per-harvest device wait (log buckets)
dvi_serving_block_accepted_drafts              histogram  PER-BLOCK accepted drafted tokens m
                                                          (exact integer buckets 0..k_max;
                                                          count==blocks_total,
                                                          sum==accepted_drafts_total)
dvi_serving_block_depth                        histogram  PER-BLOCK speculation depth k
                                                          (exact integer buckets;
                                                          count==blocks_total,
                                                          sum==drafted_tokens_total)
=============================================  =========  =====================================

The two per-block histograms are folded from the continuous superstep
harvest; under the legacy sync scheduler (no superstep dispatches) they
stay empty, and the reconciliation identities above apply only when
``dvi_serving_dispatches_total > 0`` (enforced by
``scripts/check_metrics_schema.py``).

``dvi_train_*`` — DVI drafter training-loop metrics (the paper's
feedback loop made measurable):

=============================================  =========  =====================================
dvi_train_updates_total                        counter    optimizer steps taken
dvi_train_step                                 gauge      optimizer step t (drives KL->RL)
dvi_train_phase                                gauge      0=warmup 1=ramp 2=rl (schedule phase)
dvi_train_lambda_pg / dvi_train_lambda_kl      gauge      KL->RL schedule weights at t
dvi_train_beta                                 gauge      on-policy KL coefficient beta(t)
dvi_train_loss                                 gauge      last composite loss
dvi_train_loss_kl                              gauge      KL(p_theta || p_phi^tau) term
dvi_train_loss_ce                              gauge      reward-masked CE term (L_pg)
dvi_train_loss_pg                              gauge      on-policy policy-gradient term
dvi_train_acceptance_batch                     gauge      minibatch acceptance rate
dvi_train_acceptance_ema_before / _after       gauge      reward-EMA baseline around the update
dvi_train_buffer_count                         gauge      replay-buffer occupancy (tuples)
dvi_train_gnorm                                gauge      LoRA grad norm of the last update
dvi_train_update_span_seconds                  histogram  dispatch -> fold staleness window
=============================================  =========  =====================================

The zero-host-sync contract
---------------------------

Telemetry must never add a device->host synchronization to the serving
hot path.  Every device-side observation (per-block histogram buckets,
training-loss components) rides the compact summary the engine ALREADY
materializes once per superstep (`jax.device_get` in ``_harvest``) —
in-graph counters are folded into ``SuperstepResult`` and update metrics
are staged at fold time and materialized inside the NEXT harvest's
device_get.  Host-side work (registry increments, trace events) uses the
engine's injected monotonic clock and host mirrors only.  Enforced by
``tests/test_telemetry.py``: with telemetry on, committed streams are
bit-identical and ``host_syncs`` is unchanged.
"""
from __future__ import annotations

import bisect
import json
import math
import time
from collections import deque
from collections.abc import MutableMapping
from typing import Callable, Dict, List, Optional, Sequence


# ---------------------------------------------------------------------------
# metrics: counters, gauges, log/exact-bucketed histograms
# ---------------------------------------------------------------------------

class Counter:
    """Monotonic accumulator.  ``set`` exists only for the legacy
    ``stats["key"] += n`` facade (read-modify-write) and for resets."""
    kind = "counter"

    def __init__(self, name: str, help: str):
        self.name, self.help = name, help
        self.value = 0

    def inc(self, v=1):
        self.value += v

    def set(self, v):
        self.value = v

    def reset(self):
        self.value = 0

    def to_snapshot(self) -> dict:
        return {"type": "counter", "help": self.help, "value": self.value}


class Gauge(Counter):
    """Point-in-time value (may go down)."""
    kind = "gauge"

    def set_max(self, v):
        self.value = max(self.value, v)

    def to_snapshot(self) -> dict:
        return {"type": "gauge", "help": self.help, "value": self.value}


class LabeledCounter:
    """Counter with ONE label dimension (e.g. ``tenant``): a dict of
    monotone per-label-value series.  The snapshot carries both the
    per-label ``values`` map and their total under ``value`` so scrapers
    that only understand flat counters still see the aggregate; the
    schema checker asserts the per-tenant values sum to
    ``dvi_serving_submitted_total`` exactly."""
    kind = "counter"

    def __init__(self, name: str, help: str, label: str):
        self.name, self.help, self.label = name, help, label
        self.values: Dict[str, float] = {}

    @property
    def value(self):
        return sum(self.values.values())

    def inc(self, label_value: str, v=1):
        self.values[label_value] = self.values.get(label_value, 0) + v

    def reset(self):
        self.values = {}

    def to_snapshot(self) -> dict:
        return {"type": "counter", "help": self.help, "label": self.label,
                "values": dict(self.values), "value": self.value}


def log_buckets(lo: float, hi: float, base: float = 2.0) -> List[float]:
    """Geometric bucket upper bounds from `lo` to >= `hi` (for durations:
    resolution proportional to magnitude, O(log(hi/lo)) buckets)."""
    if not (lo > 0 and hi > lo and base > 1):
        raise ValueError(f"need 0 < lo < hi and base > 1, got "
                         f"({lo}, {hi}, {base})")
    out, b = [], lo
    while b < hi:
        out.append(b)
        b *= base
    out.append(b)
    return out


class Histogram:
    """Prometheus-style histogram: per-bucket counts + sum + count.

    `buckets`: ascending upper bounds (a "+Inf" bucket is implicit).  Use
    ``observe`` for continuous values and ``add`` to fold exact integer
    bucket counts (e.g. the superstep's in-graph per-block histograms) —
    ``add(value, n)`` keeps ``sum`` exact, so the histogram reconciles
    to the flat counter it shadows with no rounding."""
    kind = "histogram"

    def __init__(self, name: str, help: str, buckets: Sequence[float]):
        bs = list(buckets)
        if bs != sorted(bs) or len(set(bs)) != len(bs):
            raise ValueError(f"{name}: bucket bounds must be strictly "
                             f"ascending, got {bs}")
        self.name, self.help = name, help
        self.bounds = bs                       # upper bounds, +Inf implicit
        self.counts = [0] * (len(bs) + 1)      # last slot = overflow (+Inf)
        self.sum = 0
        self.count = 0

    def observe(self, v, n: int = 1):
        self.counts[bisect.bisect_left(self.bounds, v)] += n
        self.sum += v * n
        self.count += n

    def add(self, value, n: int):
        """Fold `n` pre-counted observations of exact `value`."""
        if n:
            self.observe(value, n)

    def reset(self):
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0
        self.count = 0

    def to_snapshot(self) -> dict:
        cum, c = [], 0
        for b, n in zip(self.bounds + ["+Inf"], self.counts):
            c += n
            cum.append([b, c])
        return {"type": "histogram", "help": self.help, "buckets": cum,
                "sum": self.sum, "count": self.count}


class MetricsRegistry:
    """Named metrics with snapshot/delta semantics and Prometheus text
    rendering.  One flat namespace; re-registering a name is an error."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter(name, help))

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge(name, help))

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = ()) -> Histogram:
        return self._register(Histogram(name, help, buckets))

    def labeled_counter(self, name: str, help: str = "",
                        label: str = "tenant") -> LabeledCounter:
        return self._register(LabeledCounter(name, help, label))

    def _register(self, m):
        if m.name in self._metrics:
            raise ValueError(f"metric {m.name!r} already registered")
        self._metrics[m.name] = m
        return m

    def __getitem__(self, name: str):
        return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def reset(self):
        for m in self._metrics.values():
            m.reset()

    def snapshot(self) -> dict:
        """JSON-able point-in-time view of every metric."""
        return {n: self._metrics[n].to_snapshot() for n in self.names()}

    def render_prometheus(self) -> str:
        return render_prometheus(self.snapshot())


def snapshot_delta(cur: dict, prev: dict) -> dict:
    """Counter/histogram difference between two snapshots (gauges keep the
    current value — a gauge has no meaningful rate)."""
    out = {}
    for name, c in cur.items():
        p = prev.get(name)
        if p is None or c["type"] == "gauge":
            out[name] = dict(c)
        elif c["type"] == "counter":
            out[name] = dict(c, value=c["value"] - p["value"])
            if "values" in c:
                pv = p.get("values", {})
                out[name]["values"] = {k: v - pv.get(k, 0)
                                       for k, v in c["values"].items()}
        else:
            pb = {tuple([b]): n for b, n in p["buckets"]}
            out[name] = dict(
                c, sum=c["sum"] - p["sum"], count=c["count"] - p["count"],
                buckets=[[b, n - pb.get(tuple([b]), 0)]
                         for b, n in c["buckets"]])
    return out


def _fmt(v) -> str:
    if isinstance(v, float) and v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v) if isinstance(v, float) else str(v)


def _escape_label(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace("\n",
                                                                   r"\n")


def _unescape_label(v: str) -> str:
    return v.replace(r"\n", "\n").replace(r'\"', '"').replace(r"\\", "\\")


def render_prometheus(snapshot: dict) -> str:
    """Prometheus exposition text format (round-trips through
    ``parse_prometheus_text``)."""
    lines = []
    for name in sorted(snapshot):
        m = snapshot[name]
        if m.get("help"):
            lines.append(f"# HELP {name} {m['help']}")
        lines.append(f"# TYPE {name} {m['type']}")
        if m["type"] in ("counter", "gauge"):
            if "values" in m:                  # one-label counter series
                lab = m.get("label", "tenant")
                for lv in sorted(m["values"]):
                    lines.append(f'{name}{{{lab}="{_escape_label(lv)}"}} '
                                 f'{_fmt(m["values"][lv])}')
            else:
                lines.append(f"{name} {_fmt(m['value'])}")
        else:
            for b, cum in m["buckets"]:
                le = "+Inf" if b == "+Inf" else _fmt(b)
                lines.append(f'{name}_bucket{{le="{le}"}} {cum}')
            lines.append(f"{name}_sum {_fmt(m['sum'])}")
            lines.append(f"{name}_count {m['count']}")
    return "\n".join(lines) + "\n"


def parse_prometheus_text(text: str) -> dict:
    """Minimal exposition-format parser: returns the same snapshot shape
    ``MetricsRegistry.snapshot`` produces (numbers parsed back as
    int where exact).  Used by the round-trip test and as a reference
    for scrapers."""
    def num(s):
        f = float(s)
        return int(f) if f == int(f) and "inf" not in s.lower() else f

    out: dict = {}
    types: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(None, 3)
            types[name] = kind
            out[name] = ({"type": kind, "help": out.get(name, {}).get("help", ""),
                          "buckets": [], "sum": 0, "count": 0}
                         if kind == "histogram"
                         else {"type": kind,
                               "help": out.get(name, {}).get("help", ""),
                               "value": 0})
            continue
        if line.startswith("# HELP "):
            _, _, name, help_ = line.split(None, 3)
            out.setdefault(name, {})["help"] = help_
            continue
        if line.startswith("#"):
            continue
        key, val = line.rsplit(None, 1)
        if key.endswith('"}') and "_bucket{le=" in key:
            base = key[:key.index("_bucket{")]
            le = key[key.index('le="') + 4:-2]
            out[base]["buckets"].append(
                ["+Inf" if le == "+Inf" else num(le), num(val)])
        elif key.endswith('"}') and "{" in key:
            base = key[:key.index("{")]
            lab, _, lv = key[key.index("{") + 1:-2].partition('="')
            m = out[base]
            m["label"] = lab
            m.setdefault("values", {})[_unescape_label(lv)] = num(val)
            m["value"] = sum(m["values"].values())
        elif key.endswith("_sum") and key[:-4] in types \
                and types[key[:-4]] == "histogram":
            out[key[:-4]]["sum"] = num(val)
        elif key.endswith("_count") and key[:-6] in types \
                and types[key[:-6]] == "histogram":
            out[key[:-6]]["count"] = num(val)
        else:
            out[key]["value"] = num(val)
    return out


# ---------------------------------------------------------------------------
# legacy stats facade
# ---------------------------------------------------------------------------

class StatsView(MutableMapping):
    """dict-compatible facade over registry metrics plus rolling deques.

    ``view["blocks"]`` reads the bound metric's value; ``view["blocks"]
    = v`` writes it (so the engine's historical ``stats[k] += n``
    read-modify-write idiom keeps working); deque-valued entries
    (``latencies`` / ``tick_s`` / ``k_mean``) are returned as the live
    deque object.  The key set is fixed at construction — the canonical
    schema — so ad-hoc keys can no longer appear in one place and not
    another."""

    def __init__(self, metrics: Dict[str, object], deques: Dict[str, deque]):
        self._metrics = dict(metrics)
        self._deques = dict(deques)

    def __getitem__(self, k):
        if k in self._deques:
            return self._deques[k]
        return self._metrics[k].value

    def __setitem__(self, k, v):
        if k in self._deques:
            self._deques[k] = v
        elif k in self._metrics:
            self._metrics[k].set(v)
        else:
            raise KeyError(f"{k!r} is not a declared stats key "
                           f"(see ServingTelemetry)")

    def __delitem__(self, k):
        raise TypeError("stats keys are fixed by the telemetry schema")

    def __iter__(self):
        yield from self._metrics
        yield from self._deques

    def __len__(self):
        return len(self._metrics) + len(self._deques)

    def reset(self):
        for m in self._metrics.values():
            m.reset()
        for d in self._deques.values():
            d.clear()


# ---------------------------------------------------------------------------
# Chrome-trace / Perfetto lifecycle tracer
# ---------------------------------------------------------------------------

class Tracer:
    """Collects Chrome trace events ("trace event format").  Timestamps
    are microseconds on the injected monotonic clock, zeroed at tracer
    construction.  ``span`` appends a complete ``ph="X"`` event (events
    may be appended out of order — viewers sort by ts), ``instant`` a
    point event.  The event list is capped; overflow increments
    ``dropped`` instead of growing without bound."""

    def __init__(self, clock: Callable[[], float] = time.monotonic,
                 process: str = "dvi-serving", limit: int = 200_000):
        self._clock = clock
        self._t0 = clock()
        self._limit = limit
        self.dropped = 0
        self.events: List[dict] = [
            {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
             "args": {"name": process}}]

    def now(self) -> float:
        return self._clock()

    def _ts(self, t: float) -> float:
        return (t - self._t0) * 1e6

    def _emit(self, ev: dict):
        if len(self.events) >= self._limit:
            self.dropped += 1
            return
        self.events.append(ev)

    def name_track(self, tid: int, name: str):
        self._emit({"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                    "args": {"name": name}})

    def span(self, tid: int, name: str, t0: float, t1: float,
             args: Optional[dict] = None, cat: str = "serving"):
        self._emit({"name": name, "ph": "X", "pid": 0, "tid": tid,
                    "cat": cat, "ts": self._ts(t0),
                    "dur": max(self._ts(t1) - self._ts(t0), 0.0),
                    "args": args or {}})

    def instant(self, tid: int, name: str, t: Optional[float] = None,
                args: Optional[dict] = None, cat: str = "serving"):
        self._emit({"name": name, "ph": "i", "pid": 0, "tid": tid,
                    "cat": cat, "ts": self._ts(t if t is not None
                                               else self.now()),
                    "s": "t", "args": args or {}})

    # request lifecycles are ASYNC event pairs (ph "b"/"e", grouped by
    # (cat, id)): unlike per-track X spans they may overlap freely —
    # many requests sit queued at once — and Perfetto renders each id as
    # its own async row.  Phases of one request (queued / prefill /
    # decode) share its id and nest within the outer "request" pair.
    def async_begin(self, name: str, id: int, t: Optional[float] = None,
                    args: Optional[dict] = None, cat: str = "request"):
        self._emit({"name": name, "ph": "b", "pid": 0, "tid": 0,
                    "cat": cat, "id": id,
                    "ts": self._ts(t if t is not None else self.now()),
                    "args": args or {}})

    def async_end(self, name: str, id: int, t: Optional[float] = None,
                  args: Optional[dict] = None, cat: str = "request"):
        self._emit({"name": name, "ph": "e", "pid": 0, "tid": 0,
                    "cat": cat, "id": id,
                    "ts": self._ts(t if t is not None else self.now()),
                    "args": args or {}})

    def to_dict(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def write(self, path: str):
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)


def validate_trace(trace: dict) -> dict:
    """Schema-check a Chrome trace dict: required event keys, span
    durations, monotone span NESTING per track (two complete events on
    one track must either nest or be disjoint — a half-overlap means the
    emitting code attributed time to two phases at once), and balanced
    async begin/end pairing per (cat, id, name) with non-negative phase
    durations.  Returns ``{tid: [events]}`` grouped per track; raises
    ``ValueError`` on any violation."""
    evs = trace["traceEvents"]
    tracks: Dict[int, List[dict]] = {}
    opens: Dict[tuple, list] = {}
    for ev in evs:
        for k in ("name", "ph", "pid", "tid"):
            if k not in ev:
                raise ValueError(f"event missing {k!r}: {ev}")
        if ev["ph"] == "M":
            continue
        if "ts" not in ev:
            raise ValueError(f"non-metadata event missing ts: {ev}")
        if ev["ph"] == "X":
            if "dur" not in ev or ev["dur"] < 0:
                raise ValueError(f"X event needs dur >= 0: {ev}")
        if ev["ph"] in ("b", "e"):
            if "id" not in ev:
                raise ValueError(f"async event needs id: {ev}")
            k = (ev.get("cat"), ev["id"], ev["name"])
            if ev["ph"] == "b":
                opens.setdefault(k, []).append(ev["ts"])
            else:
                if not opens.get(k):
                    raise ValueError(f"async end without begin: {k}")
                t0 = opens[k].pop()
                if ev["ts"] < t0:
                    raise ValueError(
                        f"async pair {k} ends before it begins "
                        f"({t0:.1f} -> {ev['ts']:.1f})")
        tracks.setdefault(ev["tid"], []).append(ev)
    dangling = [k for k, v in opens.items() if v]
    if dangling:
        raise ValueError(f"unclosed async pairs: {dangling}")
    eps = 1e-6
    for tid, track in tracks.items():
        spans = sorted((e for e in track if e["ph"] == "X"),
                       key=lambda e: (e["ts"], -e["dur"]))
        stack: List[dict] = []
        for e in spans:
            while stack and e["ts"] >= stack[-1]["ts"] + stack[-1]["dur"] - eps:
                stack.pop()
            if stack:
                enc = stack[-1]
                if e["ts"] + e["dur"] > enc["ts"] + enc["dur"] + eps:
                    raise ValueError(
                        f"track {tid}: span {e['name']!r} "
                        f"[{e['ts']:.1f}, {e['ts'] + e['dur']:.1f}] half-"
                        f"overlaps {enc['name']!r} "
                        f"[{enc['ts']:.1f}, {enc['ts'] + enc['dur']:.1f}]")
            stack.append(e)
    return tracks


# ---------------------------------------------------------------------------
# the serving engine's canonical metric declarations
# ---------------------------------------------------------------------------

# legacy stats key -> (metric name, kind, help).  THE schema: the engine's
# stats facade, reset_stats, and the Prometheus snapshot all derive from
# this one table, so the key sets cannot drift.
LEGACY_STATS = {
    "requests": ("dvi_serving_requests_total", "counter",
                 "completed requests"),
    "submitted": ("dvi_serving_submitted_total", "counter",
                  "requests submitted (accepted + rejected)"),
    "cancelled": ("dvi_serving_cancelled_total", "counter",
                  "requests cancelled at any lifecycle stage"),
    "rejected": ("dvi_serving_rejected_total", "counter",
                 "submissions rejected with QueueFull backpressure"),
    "blocks": ("dvi_serving_blocks_total", "counter",
               "per-live-lane speculative blocks"),
    "steps": ("dvi_serving_steps_total", "counter",
              "scheduler iterations (batch block-steps)"),
    "committed": ("dvi_serving_committed_tokens_total", "counter",
                  "tokens committed by the verifier"),
    "accepted": ("dvi_serving_accepted_drafts_total", "counter",
                 "drafted tokens accepted by the verifier"),
    "drafted": ("dvi_serving_drafted_tokens_total", "counter",
                "drafted tokens proposed"),
    "updates": ("dvi_train_updates_total", "counter",
                "drafter optimizer steps"),
    "preemptions": ("dvi_serving_preemptions_total", "counter",
                    "paged-pool preempt-or-queue events"),
    "host_syncs": ("dvi_serving_host_syncs_total", "counter",
                   "device->host syncs on the serving hot path"),
    "sync_wait_s": ("dvi_serving_sync_wait_seconds_total", "counter",
                    "host seconds blocked on device results"),
    "dispatches": ("dvi_serving_dispatches_total", "counter",
                   "superstep dispatches"),
    "prefill_chunks": ("dvi_serving_prefill_chunks_total", "counter",
                       "batched prefill chunk steps"),
    "prefill_tokens": ("dvi_serving_prefill_tokens_total", "counter",
                       "prompt tokens prefilled via chunk steps"),
    "prefix_lookups": ("dvi_serving_prefix_lookups_total", "counter",
                       "prefix-cache admission lookups"),
    "prefix_hits": ("dvi_serving_prefix_hits_total", "counter",
                    "prefix lookups matching >=1 cached token"),
    "prefix_misses": ("dvi_serving_prefix_misses_total", "counter",
                      "prefix lookups matching nothing"),
    "prefix_hit_tokens": ("dvi_serving_prefix_hit_tokens_total", "counter",
                          "prompt tokens skipped via cached prefixes"),
    "prefix_cow_copies": ("dvi_serving_prefix_cow_copies_total", "counter",
                          "copy-on-write page copies at warm admission"),
    "prefix_evictions": ("dvi_serving_prefix_evictions_total", "counter",
                         "prefix-cached pages lazily reclaimed (LRU)"),
    "peak_live_slots": ("dvi_serving_peak_live_slots", "gauge",
                        "high-water concurrent live lanes"),
    "max_tick_prefill_tokens": ("dvi_serving_max_tick_prefill_tokens",
                                "gauge",
                                "largest single-tick prefill token count"),
}

# rolling-deque stats keys (windowed raw observations for percentiles;
# each shadows a registry histogram fed at the same call sites)
DEQUE_STATS = ("latencies", "tick_s", "k_mean")

# lane/queue/engine/train track layout: lanes take tids [0, num_slots)
QUEUE_TRACK = "queue"
ENGINE_TRACK = "engine"
TRAIN_TRACK = "train"


class ServingTelemetry:
    """Registry + declared metrics + (optional) tracer for one engine.

    Everything here is host-side: the engine feeds it from its single
    per-superstep harvest and its injected monotonic clock.  Attributes
    are the declared metric objects (``h_*`` histograms, ``g_*`` gauges,
    ``c_*`` counters) so engine call sites stay cheap and explicit."""

    def __init__(self, num_slots: int, k_max: int, latency_window: int,
                 clock: Callable[[], float] = time.monotonic,
                 trace: bool = False, trace_limit: int = 200_000):
        self.registry = MetricsRegistry()
        reg = self.registry
        legacy = {key: (reg.counter(name, help) if kind == "counter"
                        else reg.gauge(name, help))
                  for key, (name, kind, help) in LEGACY_STATS.items()}
        deques = {k: deque(maxlen=latency_window) for k in DEQUE_STATS}
        self.stats = StatsView(legacy, deques)

        dur = log_buckets(1e-4, 64.0)          # 100us .. 64s log2 buckets
        self.h_latency = reg.histogram(
            "dvi_serving_request_latency_seconds",
            "request submit -> completion latency", dur)
        self.h_tick = reg.histogram(
            "dvi_serving_tick_seconds", "engine tick wall time", dur)
        self.h_sync_wait = reg.histogram(
            "dvi_serving_sync_wait_seconds",
            "per-harvest host wait on the device", dur)
        self.h_queue_wait = reg.histogram(
            "dvi_serving_queue_wait_seconds",
            "request submit -> first lane admission", dur)
        self.h_ttft = reg.histogram(
            "dvi_serving_ttft_seconds",
            "request submit -> first committed token", dur)
        self.c_tenant = reg.labeled_counter(
            "dvi_serving_requests_by_tenant",
            "requests submitted per tenant (values sum to submitted_total)",
            label="tenant")
        kb = list(range(k_max + 1))            # exact integer buckets 0..k
        self.h_block_accept = reg.histogram(
            "dvi_serving_block_accepted_drafts",
            "accepted drafted tokens per speculative block "
            "(count==blocks_total, sum==accepted_drafts_total)", kb)
        self.h_block_depth = reg.histogram(
            "dvi_serving_block_depth",
            "speculation depth per live block "
            "(count==blocks_total, sum==drafted_tokens_total)", kb)
        self.c_watermark = reg.counter(
            "dvi_serving_kv_watermark_hits_total",
            "admissions blocked on pool watermark/reserve headroom")
        self.g_live = reg.gauge("dvi_serving_live_slots",
                                "currently occupied lanes")
        self.g_queue = reg.gauge("dvi_serving_queue_depth",
                                 "requests waiting for a lane")
        self.g_kv_used = reg.gauge("dvi_serving_kv_used_pages",
                                   "pool pages live (refcount > 0)")
        self.g_kv_free = reg.gauge("dvi_serving_kv_free_pages",
                                   "pool pages free or evictable")
        self.g_kv_cached = reg.gauge("dvi_serving_kv_cached_pages",
                                     "evictable prefix-cached pages")
        self.g_depth_mean = reg.gauge(
            "dvi_serving_depth_mean", "mean live-lane speculation depth")

        self.g_step = reg.gauge("dvi_train_step",
                                "drafter optimizer step t")
        self.g_phase = reg.gauge("dvi_train_phase",
                                 "KL->RL schedule phase: 0=warmup 1=ramp 2=rl")
        self.g_lambda_pg = reg.gauge("dvi_train_lambda_pg",
                                     "policy-loss weight at step t")
        self.g_lambda_kl = reg.gauge("dvi_train_lambda_kl",
                                     "KL-distillation weight at step t")
        self.g_beta = reg.gauge("dvi_train_beta",
                                "on-policy KL coefficient beta(t)")
        self.g_loss = reg.gauge("dvi_train_loss", "last composite loss")
        self.g_loss_kl = reg.gauge("dvi_train_loss_kl",
                                   "KL(p_theta || p_phi^tau) component")
        self.g_loss_ce = reg.gauge("dvi_train_loss_ce",
                                   "reward-masked CE component (L_pg)")
        self.g_loss_pg = reg.gauge("dvi_train_loss_pg",
                                   "on-policy policy-gradient component")
        self.g_acc_batch = reg.gauge("dvi_train_acceptance_batch",
                                     "acceptance rate of the update minibatch")
        self.g_ema_before = reg.gauge(
            "dvi_train_acceptance_ema_before",
            "reward-EMA baseline entering the update")
        self.g_ema_after = reg.gauge(
            "dvi_train_acceptance_ema_after",
            "reward-EMA baseline after the update")
        self.g_buffer = reg.gauge("dvi_train_buffer_count",
                                  "replay-buffer occupancy (tuples)")
        self.g_gnorm = reg.gauge("dvi_train_gnorm",
                                 "LoRA grad norm of the last update")
        self.h_update_span = reg.histogram(
            "dvi_train_update_span_seconds",
            "drafter update dispatch -> fold staleness window", dur)

        self.tracer = Tracer(clock, limit=trace_limit) if trace else None
        if self.tracer is not None:
            for s in range(num_slots):
                self.tracer.name_track(s, f"lane {s}")
            self.tid_queue = num_slots
            self.tid_engine = num_slots + 1
            self.tid_train = num_slots + 2
            self.tracer.name_track(self.tid_queue, QUEUE_TRACK)
            self.tracer.name_track(self.tid_engine, ENGINE_TRACK)
            self.tracer.name_track(self.tid_train, TRAIN_TRACK)

    def snapshot(self) -> dict:
        return self.registry.snapshot()

    def render_prometheus(self) -> str:
        return self.registry.render_prometheus()

    def write_metrics(self, path: str):
        """Write the snapshot as JSON (``*.json``) or Prometheus text."""
        if path.endswith(".json"):
            with open(path, "w") as f:
                json.dump(self.snapshot(), f, indent=1)
        else:
            with open(path, "w") as f:
                f.write(self.render_prometheus())
