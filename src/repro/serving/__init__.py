from repro.serving.engine import ServingEngine, Request, Completion

__all__ = ["ServingEngine", "Request", "Completion"]
