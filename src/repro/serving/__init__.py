from repro.serving.config import EngineConfig
from repro.serving.engine import ServingEngine, Request, Completion
from repro.serving.handles import QueueFull, RequestHandle, TenantQueue

__all__ = ["ServingEngine", "Request", "Completion", "RequestHandle",
           "QueueFull", "TenantQueue", "EngineConfig"]
