"""Continual-learning serving engine: slot-scheduled continuous batching.

The paper's deployment story — one model serving live traffic while every
verify step trains the drafter — implemented as a **slot scheduler** around
the shared speculative block-step (``spec_block_step``):

* the decode batch is a fixed set of ``num_slots`` lanes over one persistent
  cache; each lane independently holds a request at its own committed length,
* arriving requests are prefilled individually (exact prompt, no bucket
  padding) and spliced into a free lane with ``transformer.insert_slot``,
* every engine tick dispatches ONE fused **superstep** of ``sync_every``
  speculative blocks (``spec_superstep``): EOS detection, per-lane budget
  capping, token-stream assembly, and tuple logging all run in-graph, so
  the host syncs with the device once per superstep — a compact summary
  (done mask, per-lane commit counts, token buffer) — instead of once per
  block; idle lanes ride along masked ``done`` (accept = 0, no state
  change, no tuples logged),
* the dispatch is **double-buffered**: ``step()`` first admits arrivals
  into already-free lanes (those ops queue behind the in-flight superstep
  without blocking), only then harvests the in-flight summary, so host
  bookkeeping overlaps device compute instead of serializing behind it,
* lanes retire per-request on EOS or ``max_new`` — completions stream out
  at superstep boundaries (the superstep/`sync_every` contract: admission,
  retirement, and preemption happen only at boundaries; token streams stay
  bit-identical to per-block ticking, the trade is up to ``sync_every - 1``
  blocks of extra completion latency for ~``sync_every``x fewer host
  syncs/dispatches) — and the lane is reset for reuse,
* the LoRA drafter takes an update every ``update_every`` block-steps from
  the replay buffer; the update is dispatched WITHOUT blocking the engine —
  the new ``dvi_params`` are folded in at the next superstep boundary, so
  decode proceeds with (one superstep) stale drafter weights instead of
  stalling behind the optimizer (lossless: the committed stream never
  depends on drafter quality, only acceptance does),
* per-request latency (arrival -> completion; see ``latency_percentiles``)
  and per-slot acceptance are tracked so drift and stragglers are
  observable; latencies are kept in a rolling window of the most recent
  ``latency_window`` completions so long-running engines don't grow
  unboundedly.

With ``kv_pages > 0`` the continuous scheduler runs over a **paged** KV
cache (``repro.serving.kv_pool``): full-attention KV lives in a shared page
pool, lanes hold block-table rows instead of worst-case contiguous regions,
and scheduling becomes memory-aware:

* **admission** checks the free-page watermark, not just a free lane — a
  request is admitted when the pool can cover its prompt plus one
  speculative block (later growth is on demand),
* **growth**: before every superstep each live lane is topped up to cover
  the positions that superstep can touch — ``sync_every`` blocks of K+1
  eager tokens, CAPPED by the lane's remaining ``max_new`` budget (a lane
  about to retire only gets pages for the blocks it can still run) — so
  pages are allocated only as sequences grow and short/near-done requests
  no longer pay for long ones,
* **preempt-or-queue**: when the pool runs dry mid-decode, the newest lane
  is preempted — its pages return to the pool, its progress (prompt +
  generated prefix) is re-queued at the front of the FIFO and replayed via
  prefill on re-admission, which is lossless for greedy decoding,
* retirement frees the lane's pages (``reset_slot`` just unmaps the
  block-table row; no KV bytes move).

With ``prefill_chunk > 0`` prompt prefill is **chunked and scheduled**
instead of one-shot-on-admission, so a single long prompt can no longer
stall every live lane for its whole prefill:

* admission only prefills the FIRST chunk (into a chunk-sized scratch,
  spliced with ``insert_slot`` — which accepts the partially-built cache)
  and parks the lane in a PREFILL state: ``done``-masked, it rides along
  inert through supersteps (``spec_block_step`` freezes masked lanes'
  stateful-mixer state and cache length, so the partial prefill survives
  untouched),
* every tick, ONE batched **chunk step** (``model.prefill_chunk``) advances
  all prefilling lanes by up to ``prefill_chunk`` tokens each, directly in
  the live cache (contiguous or paged) — the per-tick prefill work is
  bounded by ``num_slots * prefill_chunk`` tokens regardless of prompt
  length, and decode supersteps keep firing between chunks,
* a lane that consumes its last chunk flips live the SAME tick and enters
  that tick's superstep (its pending token is set in-graph by the chunk
  step), so chunking adds no extra tick of completion latency,
* paged mode provisions pages chunk-by-chunk (``KVPool.ensure``) instead
  of whole-prompt at admission — admission is gated on the first chunk's
  pages against the watermark; later chunks are growth-class allocations
  that, like decode page growth, may dip into the watermark headroom —
  and a mid-prefill lane is preemptible exactly like a decode lane: its
  pages are freed and its request re-queued at the FIFO front (lossless —
  no tokens were generated),
* committed token streams are bit-identical to one-shot prefill (greedy
  and sampled, both layouts — tested): the chunk step is the same decode
  math at the same positions, only scheduled differently.

With ``adaptive_k=True`` speculation depth becomes a per-lane runtime
quantity driven by the verifier's accept/reject stream (the paper's
training-aware thesis applied to the speculative machinery itself, not
just the drafter weights):

* each lane carries depth-controller state (depth ``k``, acceptance EMA,
  cooldown — see ``repro.core.schedule.DepthConfig``); the controller runs
  IN-GRAPH inside the fused superstep, so depth adapts per block with zero
  extra host syncs and changes apply only at block boundaries,
* the host mirrors the controller state per slot (harvested with the
  superstep summary, reset to ``k_init`` on admission, so a recycled lane
  never inherits the previous request's depth),
* every dispatch drafts ``K_blk = max`` over the live lanes' depth
  ceilings — when the whole batch throttles down (e.g. post-drift while
  the drafter relearns), the superstep re-specializes to a SHALLOWER draft
  scan and each block gets genuinely cheaper (this is where adaptive depth
  buys wall-clock, not just accounting; at most ``k_max`` distinct
  compilations),
* paged-pool math splits by purpose (the adaptive-depth contract, see
  ROADMAP): reservation-class computations (admission gating, pre-admission
  reserves, prompt trimming, cache capacity) use the worst-case ``k_max``;
  growth-class computations provision each lane for its LIVE depth plus the
  bounded number of rises the controller could make within one superstep
  (``schedule.max_depth_rises``), and that same bound is passed back into
  the graph as a hard ceiling ``k_cap`` — an in-graph rise can never outrun
  the pages provisioned for it, so low-acceptance lanes stop hoarding pool
  headroom without risking committed KV,
* greedy committed streams are depth-independent (speculative decoding is
  lossless for ANY k), so turning the controller on changes throughput and
  compute, never tokens; with ``adaptive_k=False`` the engine takes the
  fixed-depth code path untouched.

``scheduler="sync"`` keeps the legacy batch-synchronous path (bucket by
prompt length, decode a whole batch to completion with
``speculative_generate``) for comparison — ``benchmarks/serving_bench.py``
races the two on the same Poisson arrival trace.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import online as online_mod
from repro.core import schedule as schedule_mod
from repro.core import spec as spec_mod
from repro.models import transformer as tfm
from repro.models.model import Model
from repro.serving.handles import QueueFull, RequestHandle, TenantQueue
from repro.serving.kv_pool import KVPool
from repro.serving.telemetry import ServingTelemetry


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (Tp,) int32
    max_new: int = 64
    tenant: str = "default"       # weighted-fair queue bucket
    priority: int = 0             # within-tenant ordering (higher first)


@dataclass
class Completion:
    uid: int
    tokens: np.ndarray            # full stream (prompt + generated)
    gen_tokens: np.ndarray        # generated tokens only
    mat: float                    # mean accepted tokens/block for this request
    wall_s: float                 # engine time attributed to this request
    # submit -> completion wall time.  Superseded by the RequestHandle
    # timestamp set (queue-wait / prefill / decode split via
    # ``handle.timings()``); kept for existing consumers of the flat value.
    latency_s: float = 0.0


@dataclass
class _Slot:
    """Host-side bookkeeping for one live lane of the decode batch."""
    uid: int
    prompt: np.ndarray
    max_new: int
    gen: List[int] = field(default_factory=list)
    blocks: int = 0
    wall_s: float = 0.0
    cache_len: int = 0            # committed cache length (paged growth)
    admit_seq: int = 0            # admission order (paged preemption picks max)
    pf_prompt: Optional[np.ndarray] = None  # trimmed replay source (chunked)
    pf_pos: Optional[int] = None  # prompt tokens prefilled; None = decoding
    handle: Optional[RequestHandle] = None  # caller-facing async view


@dataclass
class ServingEngine:
    model: Model
    params: dict
    state: online_mod.OnlineTrainerState
    scheduler: str = "sync"       # "sync" (legacy batch) | "continuous"
    num_slots: int = 8            # continuous: lanes in the decode batch
    batch_size: int = 8           # sync: requests per batch
    max_new: int = 64             # default / cap for generation length
    buckets: tuple = (16, 32, 64, 128)
    updates_per_batch: int = 1    # sync: drafter updates after each batch
    update_every: int = 4         # continuous: blocks between drafter updates
    sync_every: int = 1           # continuous: blocks fused per device sync
    latency_window: int = 4096    # rolling window of completion latencies
    learn: bool = True
    lr: float = 1e-3
    mode: str = "full"
    eos_id: int = 1
    cache_len: int = 0            # continuous cache capacity (0 = derive)
    kv_pages: int = 0             # >0: paged KV pool with this many pages
    kv_page_size: int = 16        # tokens per page (paged mode)
    kv_watermark: int = 0         # pages kept free at admission (paged mode)
    prefix_cache: bool = False    # paged: share page-aligned prompt prefixes
    prefill_chunk: int = 0        # >0: prefill in chunks of this many tokens
    adaptive_k: bool = False      # per-lane acceptance-driven depth control
    k_min: int = 1                # adaptive: depth floor
    k_max: int = 0                # adaptive: depth ceiling (0 = cfg.dvi.k_spec)
    depth_cfg: Optional[schedule_mod.DepthConfig] = None  # full override
    # monotonic clock for every elapsed-duration read (injectable so timing
    # behaviour is testable deterministically; see tests/test_telemetry.py)
    clock: Callable[[], float] = time.monotonic
    telemetry: bool = False       # lifecycle tracer on (metrics always on)
    trace_limit: int = 200_000    # tracer event cap (overflow -> dropped)
    profile_dir: Optional[str] = None  # jax.profiler capture dir (optional)
    profile_steps: int = 32       # dispatches inside the capture window
    max_queue: int = 0            # admission queue bound (0 = unbounded);
                                  # submissions past it raise QueueFull
    tenant_weights: Optional[Dict[str, float]] = None  # WFQ shares (def. 1)
    _queue: Dict[int, List[Request]] = field(default_factory=dict)
    # registry-backed stats facade; built in __post_init__ from the ONE
    # canonical schema (telemetry.LEGACY_STATS) — do not pass explicitly
    stats: object = None

    def __post_init__(self):
        model, cfg = self.model, self.model.cfg
        K = cfg.dvi.k_spec
        if self.prefill_chunk and self.scheduler != "continuous":
            raise ValueError("chunked prefill requires scheduler='continuous'")
        # ring caches absorb at most RING_SLACK eager tokens beyond the live
        # window, and idle lanes see a chunk step's writes as eager garbage
        # (rolled back by length masking, like rejected speculative tokens) —
        # so the chunk is clamped to the slack the rollback rule guarantees
        self._chunk = min(max(0, int(self.prefill_chunk)), tfm.RING_SLACK)
        # adaptive depth: controller config, plus the WORST-CASE depth that
        # every reservation-class computation (cache capacity, prompt
        # trimming, admission gating, pre-admission reserves) must assume —
        # the adaptive-depth contract.  Growth-class computations use the
        # live per-lane depth instead (see _lane_growth_k).
        if self.adaptive_k and self.scheduler != "continuous":
            raise ValueError("adaptive_k requires scheduler='continuous'")
        if self.adaptive_k:
            kmax = self.k_max or K
            self._depth = self.depth_cfg or schedule_mod.DepthConfig(
                k_min=self.k_min, k_max=kmax,
                k_init=min(max(K, self.k_min), kmax))
            self._k_worst = self._depth.k_max
        else:
            self._depth = None
            self._k_worst = K
        self._cap = self.cache_len or (max(self.buckets) + self.max_new
                                       + self._k_worst + 2 + tfm.RING_SLACK)
        self._update_fn = online_mod.make_update_fn(self.model, self.mode,
                                                    self.lr)
        self._key = jax.random.PRNGKey(1234)

        # continuous state: one persistent cache, host-side slot table
        self._slots: List[Optional[_Slot]] = [None] * self.num_slots
        self._done = np.ones((self.num_slots,), bool)
        self._pending = jnp.zeros((self.num_slots,), jnp.int32)
        self._cache: Optional[dict] = None
        self._slot_accepted = np.zeros((self.num_slots,), np.int64)
        self._slot_drafted = np.zeros((self.num_slots,), np.int64)
        self._slot_committed = np.zeros((self.num_slots,), np.int64)
        self._slot_blocks = np.zeros((self.num_slots,), np.int64)
        # host mirror of the per-lane depth-controller state: uploaded at
        # dispatch, harvested with the superstep summary, reset to k_init on
        # admission (so a recycled lane starts fresh).  Kept even when the
        # controller is off (then it just pins k == k_spec in the stats).
        ki = self._depth.k_init if self._depth is not None else K
        ei = self._depth.ema_init if self._depth is not None else 0.0
        self._k_host = np.full((self.num_slots,), ki, np.int32)
        self._ema_host = np.full((self.num_slots,), ei, np.float32)
        self._cool_host = np.zeros((self.num_slots,), np.int32)
        self._submit_t: Dict[int, float] = {}
        self._blocks_since_update = 0
        # redesigned request surface: per-tenant weighted-fair admission
        # queue (single default tenant degenerates to the legacy FIFO order
        # exactly) + live handles for every accepted, unfinished request
        self._tq = TenantQueue(max_queue=self.max_queue,
                               weights=self.tenant_weights)
        self._handles: Dict[int, RequestHandle] = {}

        # telemetry: the metrics registry (and the legacy `stats` facade
        # over it) is ALWAYS on — it is pure host-side arithmetic riding
        # observations the engine already materializes; the lifecycle
        # tracer allocates only when `telemetry=True`.  The zero-host-sync
        # contract (see telemetry.py) is enforced by tests.
        self.telem = ServingTelemetry(
            num_slots=self.num_slots, k_max=self._k_worst,
            latency_window=self.latency_window, clock=self.clock,
            trace=self.telemetry, trace_limit=self.trace_limit)
        self.stats = self.telem.stats
        # host mirror of the optimizer step (drives the KL->RL schedule
        # gauges without touching the device on the hot path) and a bounded
        # history of per-update training metrics for timeline reports
        self._step_host = int(self.state.step)
        self.train_history: deque = deque(maxlen=1024)
        self._train_staged = None      # update metrics safe to materialize
        self._train_fold_note = None   # metrics folded THIS harvest
        self._profile_active = False
        self._profile_left = 0

        # ONE jitted generation entry point (jit shape-specializes on
        # `prompts`, so per-bucket closure caching was pure duplication);
        # max_new is threaded as a static arg, not a Python closure.
        def gen(params, dvi_params, prompts, buf, live, max_new):
            return spec_mod.speculative_generate(
                model, params, dvi_params, prompts, max_new,
                collect=True, buf=buf, live_mask=live)
        self._gen = jax.jit(gen, static_argnums=(5,))

        # the fused multi-block tick: sync_every blocks per device dispatch,
        # commit/EOS/budget handling in-graph (see spec_superstep)
        S = max(1, int(self.sync_every))
        self.sync_every = S
        eos = self.eos_id

        def superstep(params, dvi_params, pending, cache, buf, done, budget):
            return spec_mod.spec_superstep(
                model, params, dvi_params, pending, cache, steps=S,
                done=done, budget=budget, eos_id=eos, buf=buf, collect=True)
        self._superstep_fn = jax.jit(superstep)

        # adaptive-depth superstep: same fused loop, plus the in-graph depth
        # controller.  K_blk — the draft-scan width this dispatch — is a
        # STATIC arg: when every live lane has throttled down, the superstep
        # re-specializes to a shallower (cheaper) draft scan.  At most k_max
        # distinct compilations, cached by jit like chunk shapes.
        depth = self._depth

        def superstep_adaptive(params, dvi_params, pending, cache, buf, done,
                               budget, k, ema, cool, kcap, K_blk):
            return spec_mod.spec_superstep(
                model, params, dvi_params, pending, cache, steps=S,
                done=done, budget=budget, eos_id=eos, buf=buf, collect=True,
                k_spec=K_blk, k_lane=k, depth_cfg=depth, accept_ema=ema,
                k_cool=cool, k_cap=kcap)
        self._superstep_adaptive_fn = (
            jax.jit(superstep_adaptive, static_argnums=(11,))
            if depth is not None else None)
        # (SuperstepResult futures, engine-clock mark, occupied lanes)
        self._inflight: Optional[tuple] = None
        # drafter update dispatched but not yet folded into self.state
        self._update_inflight: Optional[tuple] = None
        # engine-resident clock: total time spent inside _step_continuous.
        # Per-request wall_s is attributed from THIS clock, so caller think
        # time between step() calls is never billed to lanes' compute.
        self._clock = 0.0
        self._tick_t0: Optional[float] = None

        cap = self._cap

        # paged KV pool: host-side ownership; block tables live in the cache
        self.paged = self.kv_pages > 0
        self._pool: Optional[KVPool] = None
        self._admit_seq = 0
        self._preempted: Dict[int, tuple] = {}   # uid -> (orig prompt, gen)
        if self.paged:
            if self.scheduler != "continuous":
                raise ValueError("paged KV requires scheduler='continuous'")
            self._pool = KVPool(self.kv_pages, self.kv_page_size)
            self._mps = self._pool.pages_for(cap)      # block-table width
            # host mirror of cache["tbl"]: per-tick page growth batches every
            # lane's row update into ONE device push (set_block_tables)
            # instead of one map_slot_pages dispatch per lane per allocation
            self._tbl_host = np.full((self.num_slots, self._mps), -1, np.int32)
            if self.kv_pages - self.kv_watermark < self._mps:
                raise ValueError(
                    f"kv_pages={self.kv_pages} minus watermark="
                    f"{self.kv_watermark} cannot hold one worst-case request "
                    f"({self._mps} pages of {self.kv_page_size}) — admission "
                    f"would livelock")
        # prefix caching: content-addressed sharing of page-aligned prompt
        # prefixes.  Requires the paged pool (the sharing substrate), the
        # chunked-prefill path (uncached TAILS are prefilled at offset
        # positions inside the live cache — scratch prefill always encodes
        # RoPE from 0, so it cannot build a tail), and a pure full-attention
        # stack (ring/SSM/RG-LRU segments hold per-lane state that cannot
        # be shared by prefix content).
        if self.prefix_cache:
            if not self.paged:
                raise ValueError("prefix_cache requires a paged KV pool "
                                 "(kv_pages > 0)")
            if self._chunk <= 0:
                raise ValueError("prefix_cache requires prefill_chunk > 0 — "
                                 "uncached prompt tails ride the chunked-"
                                 "prefill path")
            bad = [s.kind for s in tfm.model_segments(cfg) if s.kind != "attn"]
            if bad:
                raise ValueError(f"prefix_cache requires a pure full-"
                                 f"attention stack; got segment kinds {bad}")
        self._evict_seen = 0          # pool eviction counter folded per tick

        def admit(params, cache, pending, prompt, slot):
            _, pc, _ = model.prefill(params, prompt[None, :-1], max_len=cap)
            cache = tfm.insert_slot(cfg, cache, pc, slot)
            pending = jax.lax.dynamic_update_slice_in_dim(
                pending, prompt[-1:], slot, 0)
            return pending, cache
        self._admit_fn = jax.jit(admit)

        def admit_paged(params, cache, pending, prompt, slot, row):
            cache = tfm.map_slot_pages(cache, slot, row)
            # prefill scratch is prompt-sized, not worst-case-sized: the
            # splice through the block table is what lands it in the pool
            _, pc, _ = model.prefill(params, prompt[None, :-1],
                                     max_len=prompt.shape[0] - 1)
            cache = tfm.insert_slot(cfg, cache, pc, slot)
            pending = jax.lax.dynamic_update_slice_in_dim(
                pending, prompt[-1:], slot, 0)
            return pending, cache
        self._admit_paged_fn = jax.jit(admit_paged)

        def admit_prefix(cache, pending, slot, row, length, cow_src, cow_dst,
                         tok, live):
            # warm admission (prefix-cache hit): the lane's cached prefix is
            # spliced in via the block TABLE only — zero prefill compute,
            # zero KV moves for full shared pages.  A partially-matched
            # cached page is COW-copied into the lane's first writable page
            # (cow_src == cow_dst == 0 makes that a null-page no-op).
            # `live`: a fully-cached prompt skips prefill entirely — its
            # pending token is set here and the lane decodes THIS tick.
            cache = tfm.copy_page(cache, cow_src, cow_dst)
            cache = tfm.map_slot_pages(cache, slot, row)
            cache = tfm.insert_slot(cfg, cache, None, slot, shared_len=length)
            cur = jax.lax.dynamic_slice_in_dim(pending, slot, 1, 0)
            pending = jax.lax.dynamic_update_slice_in_dim(
                pending, jnp.where(live, tok, cur[0])[None], slot, 0)
            return pending, cache
        self._admit_prefix_fn = jax.jit(admit_prefix)

        def admit_chunk(params, cache, chunk, slot):
            # chunked admission (contiguous): prefill ONLY the first chunk
            # into a chunk-sized scratch — admission device work is O(chunk),
            # not O(prompt) — and splice the partially-built cache into the
            # (reset, hence inert-tailed) lane
            _, pc, _ = model.prefill(params, chunk[None, :],
                                     max_len=chunk.shape[0])
            return tfm.insert_slot(cfg, cache, pc, slot)
        self._admit_chunk_fn = jax.jit(admit_chunk)

        def chunk_step(params, cache, pending, tokens, take, finish_tok,
                       finished):
            # ONE batched prefill-chunk step: every prefilling lane advances
            # by take[s] tokens (0 = lane rides along untouched); lanes that
            # consume their last prompt token get their pending set in-graph
            # so they can enter THIS tick's superstep
            _, cache = model.prefill_chunk(params, tokens, cache, take)
            return jnp.where(finished, finish_tok, pending), cache
        self._chunk_fn = jax.jit(chunk_step)

        self._set_tbl_fn = jax.jit(tfm.set_block_tables)
        self._reset_fn = jax.jit(
            lambda cache, slot: tfm.reset_slot(cfg, cache, slot))

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def submit_request(self, req: Request) -> RequestHandle:
        """Accept `req` into the admission queue and return its handle.

        The handle is the caller's async view: ``deltas()`` streams
        generated-token chunks as superstep boundaries harvest them,
        ``result()`` blocks for the Completion, ``cancel()`` requests
        retirement at the next boundary.  When the queue is bounded
        (``max_queue``) and full, the submission is REJECTED: the
        ``rejected`` counter increments, the returned-would-be handle is
        finished with outcome ``"rejected"``, and ``QueueFull`` is raised
        (it carries the handle as ``exc.handle``)."""
        now = self.clock()
        h = RequestHandle(req.uid, getattr(req, "tenant", "default"),
                          int(getattr(req, "priority", 0)), clock=self.clock)
        h.t_submit = now
        # submitted / per-tenant counters include rejected submissions, so
        # submitted == completed + cancelled + rejected + still-queued +
        # live reconciles exactly (scripts/check_metrics_schema.py)
        self.stats["submitted"] += 1
        self.telem.c_tenant.inc(h.tenant)
        if self.scheduler == "continuous":
            try:
                self._tq.push(req)
            except QueueFull as e:
                self.stats["rejected"] += 1
                h.finish(None, "rejected", t_done=now)
                e.handle = h
                raise
        else:
            b = self._bucket(len(req.prompt))
            self._queue.setdefault(b, []).append(req)
        self._handles[req.uid] = h
        self._submit_t[req.uid] = now
        tr = self.telem.tracer
        if tr is not None and self.scheduler == "continuous":
            tr.async_begin("request", req.uid, now,
                           args={"prompt_len": int(len(req.prompt)),
                                 "max_new": int(req.max_new),
                                 "tenant": h.tenant})
            tr.async_begin("queued", req.uid, now)
        if self.scheduler == "continuous":
            self.telem.g_queue.set(len(self._tq))
        return h

    def submit(self, req: Request) -> RequestHandle:
        """Deprecated fire-and-forget submission (pre-handle API).  Thin
        shim over ``submit_request`` — the committed token stream is
        bit-identical; only the return surface changed."""
        warnings.warn(
            "ServingEngine.submit(Request) is deprecated; use "
            "submit_request(Request) -> RequestHandle (deltas/result/"
            "cancel)", DeprecationWarning, stacklevel=2)
        return self.submit_request(req)

    def _pad(self, req: Request, bucket: int) -> np.ndarray:
        p = req.prompt[-bucket:]
        if len(p) < bucket:                      # left-pad by repeating BOS
            p = np.concatenate([np.full(bucket - len(p), p[0], p.dtype), p])
        return p

    # ------------------------------------------------------------------
    # drafter updates (shared)
    # ------------------------------------------------------------------

    def _drafter_update(self, n: int) -> None:
        for _ in range(n):
            t_disp = self.clock()
            step_u = self._step_host
            self._key, sub = jax.random.split(self._key)
            (self.state.dvi_params, self.state.opt_state,
             self.state.baseline, _m) = self._update_fn(
                self.params, self.state.dvi_params, self.state.opt_state,
                self.state.buf, self.state.baseline, self.state.step, sub)
            self.state.step = self.state.step + 1
            self.stats["updates"] += 1
            self._note_update_dispatched()
            # legacy sync path: the metrics stay device-resident; the
            # train_telemetry() accessor materializes them off the hot path
            self._train_staged = (_m, t_disp, self.clock(), step_u)

    def _note_update_dispatched(self) -> None:
        """Advance the host step mirror + schedule-phase gauges — pure host
        math (`schedule.phase_info`), no device touch."""
        self._step_host += 1
        ph = schedule_mod.phase_info(self._step_host, self.model.cfg.dvi)
        t = self.telem
        t.g_step.set(self._step_host)
        t.g_phase.set(ph["phase"])
        t.g_lambda_pg.set(ph["lambda_pg"])
        t.g_lambda_kl.set(ph["lambda_kl"])
        t.g_beta.set(ph["beta"])

    def _complete(self, uid: int, tokens: np.ndarray, gen_tokens: np.ndarray,
                  mat: float, wall_s: float) -> Completion:
        now = self.clock()
        lat = now - self._submit_t.pop(uid, now)
        self.stats["latencies"].append(lat)
        self.telem.h_latency.observe(lat)
        tr = self.telem.tracer
        if tr is not None and self.scheduler == "continuous":
            tr.async_end("decode", uid, now,
                         args={"gen_tokens": int(len(gen_tokens))})
            tr.async_end("request", uid, now,
                         args={"latency_s": lat, "mat": mat})
        return Completion(uid=uid, tokens=tokens, gen_tokens=gen_tokens,
                          mat=mat, wall_s=wall_s, latency_s=lat)

    # ------------------------------------------------------------------
    # handle finalization + cancellation (boundary-only)
    # ------------------------------------------------------------------

    def _finish_handle(self, uid: int, comp: Completion,
                       outcome: str = "completed") -> None:
        """Terminal handle transition: deliver any final tokens, observe
        TTFT if this is the first delivery (sync path: tokens arrive only
        at completion), stamp t_done, wake every waiter."""
        h = self._handles.pop(uid, None)
        if h is None:
            return
        if comp is not None and len(comp.gen_tokens):
            first = h.t_first_token is None
            h.feed(comp.gen_tokens)
            if first and h.t_first_token is not None:
                self.telem.h_ttft.observe(
                    h.t_first_token - (h.t_submit if h.t_submit is not None
                                       else h.t_first_token))
        h.finish(comp, outcome)

    def _finish_cancelled_queued(self, uid: int) -> None:
        """Cancel honored while the request sat in the admission queue (or
        a preemption replay): no lane, no pages — pure bookkeeping."""
        orig_prompt, gen0, blocks0, wall0, _ = self._preempted.pop(
            uid, (None, [], 0, 0.0, None))
        self._submit_t.pop(uid, None)
        self.stats["cancelled"] += 1
        now = self.clock()
        tr = self.telem.tracer
        if tr is not None and self.scheduler == "continuous":
            tr.async_end("queued", uid, now, args={"cancelled": True})
            tr.async_end("request", uid, now, args={"cancelled": True})
        h = self._handles.pop(uid, None)
        if h is not None:
            gen = np.asarray(gen0, np.int32)
            prompt = (np.asarray(orig_prompt, np.int32)
                      if orig_prompt is not None else np.zeros(0, np.int32))
            h.finish(Completion(uid=uid,
                                tokens=np.concatenate([prompt, gen]),
                                gen_tokens=gen,
                                mat=len(gen0) / max(blocks0, 1),
                                wall_s=wall0),
                     "cancelled", t_done=now)

    def _cancel_lane(self, s: int) -> None:
        """Retire live lane `s` on a cancel request — at a superstep
        boundary ONLY (the caller guarantees no superstep is in flight):
        free/decref its pages (prefix-shared included — published prefixes
        stay cached and evictable for the next tenant), unmap its row,
        reset the lane, and finish the handle with the committed-so-far
        partial stream.  Adds NO device_get: reset/unmap queue like any
        other boundary op."""
        st = self._slots[s]
        uid, mid_prefill = st.uid, st.pf_pos is not None
        if self.paged:
            self._pool.free(uid)         # decref: shared pages survive in
            self._tbl_host[s] = -1       # the prefix cache, owned ones free
        self._cache = self._reset_fn(self._cache, jnp.int32(s))
        self._slots[s] = None
        self._done[s] = True
        self._preempted.pop(uid, None)
        self._submit_t.pop(uid, None)
        self.stats["cancelled"] += 1
        now = self.clock()
        tr = self.telem.tracer
        if tr is not None:
            tr.instant(s, "cancel", now,
                       args={"uid": uid, "gen_len": len(st.gen),
                             "mid_prefill": mid_prefill})
            tr.async_end("prefill" if mid_prefill else "decode", uid, now,
                         args={"cancelled": True})
            tr.async_end("request", uid, now, args={"cancelled": True})
        h = self._handles.pop(uid, None)
        if h is not None:
            gen = np.asarray(st.gen, np.int32)
            h.finish(Completion(uid=uid,
                                tokens=np.concatenate([st.prompt, gen]),
                                gen_tokens=gen,
                                mat=len(st.gen) / max(st.blocks, 1),
                                wall_s=st.wall_s),
                     "cancelled", t_done=now)

    def _sweep_cancels(self) -> None:
        """Honor pending ``handle.cancel()`` flags.  Runs right after the
        harvest — the one point in the tick where no superstep is in
        flight, so retiring a lane (pages freed, row unmapped, cache
        reset) cannot race device work that still reads those pages.
        Queued requests are dropped from the tenant queue; live lanes
        (decoding OR mid-chunked-prefill) are retired in place.  Lanes
        untouched by the sweep keep their state byte-for-byte, so their
        committed streams stay bit-identical (tested)."""
        want = [uid for uid, h in self._handles.items()
                if h.cancel_requested and not h.finished]
        if not want:
            return
        in_slot = {st.uid: s for s, st in enumerate(self._slots)
                   if st is not None}
        queued = set(want) - set(in_slot)
        if queued:
            for req in self._tq.drop(queued):
                self._finish_cancelled_queued(req.uid)
        for uid in want:
            s = in_slot.get(uid)
            if s is not None:
                self._cancel_lane(s)

    def abort_pending(self, reason: str) -> None:
        """Fail every unfinished handle (engine thread crashed, or shutdown
        without drain): unblocks all blocked consumers with outcome
        ``"error"``.  Engine device state is NOT touched."""
        for h in list(self._handles.values()):
            h.abort(reason)
        self._handles.clear()

    # ------------------------------------------------------------------
    # sync scheduler (legacy batch path)
    # ------------------------------------------------------------------

    def _step_sync(self) -> List[Completion]:
        """Serve one batch from the fullest bucket; maybe update the drafter."""
        # cancels are honored at batch formation (the sync path's only
        # scheduling boundary): cancelled waiters never enter a batch
        for b, lst in list(self._queue.items()):
            keep = []
            for r in lst:
                hc = self._handles.get(r.uid)
                if hc is not None and hc.cancel_requested:
                    self._finish_cancelled_queued(r.uid)
                else:
                    keep.append(r)
            self._queue[b] = keep
        if not any(self._queue.values()):
            return []
        bucket = max(self._queue, key=lambda b: len(self._queue[b]))
        reqs = self._queue[bucket][:self.batch_size]
        self._queue[bucket] = self._queue[bucket][self.batch_size:]
        n_real = len(reqs)
        t_b = self.clock()
        for r in reqs:
            hb = self._handles.get(r.uid)
            if hb is not None and hb.t_admit is None:
                hb.t_admit = t_b
                self.telem.h_queue_wait.observe(
                    t_b - (hb.t_submit if hb.t_submit is not None else t_b))
        while len(reqs) < self.batch_size:       # pad batch with replays
            reqs.append(reqs[-1])
        # padded lanes are masked out of generation, tuple logging, and stats
        live = jnp.arange(self.batch_size) < n_real
        prompts = jnp.asarray(np.stack([self._pad(r, bucket) for r in reqs]))

        t0 = self.clock()
        res = self._gen(self.params, self.state.dvi_params, prompts,
                        self.state.buf, live, int(self.max_new))
        jax.block_until_ready(res.tokens)
        wall = self.clock() - t0
        self.state.buf = res.buffer

        if self.learn:
            self._drafter_update(self.updates_per_batch)

        mat = float(res.committed) / max(float(res.blocks), 1.0)
        self.stats["requests"] += n_real
        self.stats["blocks"] += int(res.blocks)
        self.stats["committed"] += int(res.committed)
        self.stats["accepted"] += int(res.accepted_drafts)
        self.stats["drafted"] += int(res.drafted)

        outs = []
        toks = np.asarray(res.tokens)
        lens = np.asarray(res.lengths)
        for i, r in enumerate(reqs[:n_real]):
            # the batch decodes to the engine-wide max_new (head-of-line cost
            # of sync scheduling) but the client only gets what it asked for
            gen = toks[i, bucket:lens[i]][:min(r.max_new, self.max_new)]
            comp = self._complete(
                r.uid, np.concatenate([toks[i, :bucket], gen]), gen,
                mat, wall / n_real)
            outs.append(comp)
            self._finish_handle(r.uid, comp)
        return outs

    # ------------------------------------------------------------------
    # continuous scheduler (slot-based)
    # ------------------------------------------------------------------

    @property
    def active_slots(self) -> int:
        return sum(s is not None for s in self._slots)

    def _trim_prompt(self, req: Request, remaining_new: int) -> np.ndarray:
        """`remaining_new`: generation budget still outstanding — the full
        max_new for fresh requests, minus tokens already generated for
        re-queued preempted ones (whose prompt carries that prefix, so the
        worst-case capacity check must not double-count it)."""
        cfg = self.model.cfg
        prompt = np.asarray(req.prompt, np.int32)
        if len(prompt) < 2:                  # need prefill + pending
            prompt = np.concatenate(
                [np.full(2 - len(prompt), prompt[0], np.int32), prompt])
        # oversized prompts keep their suffix (mirrors the sync path's
        # `_pad` truncation) rather than crashing the serving loop.  A chunk
        # step's eager writes past a full-length idle lane's committed
        # prefix need no extra margin here: full caches CLIP out-of-capacity
        # writes (spread_write wrap=False) instead of ring-wrapping them.
        # Worst-case depth, not live depth: capacity is reservation-class.
        limit = self._cap - remaining_new - self._k_worst - 2
        if len(prompt) > limit:
            prompt = prompt[-limit:]
        return prompt

    def _first_chunk(self, prompt: np.ndarray) -> int:
        """Prompt tokens prefilled AT ADMISSION: the whole prompt (minus the
        pending token) when one-shot or when it fits one chunk; else exactly
        one chunk, with the rest scheduled tick-by-tick."""
        n = len(prompt) - 1
        return min(self._chunk, n) if self._chunk else n

    def _prefill_extent(self, st: _Slot) -> tuple:
        """(take, finishing, cache extent) for lane `st`'s next prefill
        chunk.  A finishing chunk must also provision the first-superstep
        horizon — the lane flips live THIS tick and runs the superstep on
        this provisioning alone (same rule as one-shot admission)."""
        rest = len(st.pf_prompt) - 1 - st.pf_pos
        take = min(self._chunk, rest)
        extent = st.pf_pos + take
        finishing = take == rest
        if finishing:
            extent += self._superstep_horizon(st.max_new - len(st.gen)) + 1
        return take, finishing, extent

    def _superstep_horizon(self, remaining: int, k: Optional[int] = None) -> int:
        """Cache slots one superstep can touch beyond a lane's committed
        length: ``sync_every`` blocks of K+1 eager tokens, capped by the
        lane's remaining generation budget (a lane that can only run r more
        blocks before retiring advances the cache at most r + K slots).
        The ONE formula shared by admission sizing and page growth — they
        must stay in lockstep, since lanes admitted after the tick's growth
        pass run their first superstep on admission's provisioning alone.

        `k`: the depth to assume.  Defaults to the worst case (``k_max``
        when adaptive, else ``k_spec``) — what every reservation-class
        caller must use; growth passes the lane's live depth bound
        (``_lane_growth_k``) instead, per the adaptive-depth contract."""
        K = self._k_worst if k is None else k
        return min(self.sync_every * (K + 1), remaining + K)

    def _pages_needed(self, cache_len: int, remaining: int,
                      k: Optional[int] = None) -> int:
        """Pages covering `cache_len` committed slots plus one superstep
        horizon (+1 slack slot, the pre-superstep rule since PR 3)."""
        return self._pool.pages_for(
            cache_len + self._superstep_horizon(remaining, k) + 1)

    def _lane_growth_k(self, s: int) -> int:
        """The depth bound lane `s` is provisioned for over its NEXT
        superstep: its live depth plus the (cooldown-limited) rises the
        in-graph controller could make within ``sync_every`` blocks.  This
        same bound is passed back into the superstep as ``k_cap``, so the
        provisioning and the controller's reachable depths are mutually
        consistent by construction — pages can never be outrun."""
        if self._depth is None:
            return self.model.cfg.dvi.k_spec
        rises = schedule_mod.max_depth_rises(
            self._depth, self.sync_every, int(self._cool_host[s]))
        return min(self._depth.k_max, int(self._k_host[s]) + rises)

    def _growth_reserve(self) -> int:
        """Upper bound on the pages live lanes may still need for their
        NEXT growth pass, assuming the in-flight superstep commits its full
        horizon.  Pre-admission (which runs BEFORE harvest + growth) keeps
        this many pages untouched so a new request never grabs pages that
        older live lanes immediately claw back by preempting it."""
        reserve = 0
        for st in self._slots:
            if st is None:
                continue
            if st.pf_pos is not None:    # mid-prefill: next chunk's demand
                continue                 # (counted by _prefill_reserve)
            remaining = st.max_new - len(st.gen)
            if remaining <= 0:
                continue
            inflight_cap = st.cache_len + self._superstep_horizon(remaining)
            need = self._pages_needed(inflight_cap, remaining)
            reserve += max(0, need - len(self._pool.owned(st.uid)))
        return reserve + self._prefill_reserve()

    def _prefill_reserve(self) -> int:
        """Pages mid-prefill lanes will claim for their NEXT chunk (plus the
        finishing-chunk superstep horizon).  BOTH admission sites must keep
        these untouched — ``_advance_prefill`` consumes them right after the
        post-growth admission, so admitting a request into them would only
        get it preempted by a senior prefill lane the same tick (a wasted
        admission prefill per tick for the rest of the long prefill)."""
        reserve = 0
        for st in self._slots:
            if st is None or st.pf_pos is None:
                continue
            _, _, extent = self._prefill_extent(st)
            need = self._pool.pages_for(extent)
            reserve += max(0, need - len(self._pool.owned(st.uid)))
        return reserve

    def _admit_waiting(self, reserve: int = 0) -> None:
        """Prefill-on-arrival: splice queued requests into free lanes.
        Paged mode additionally gates admission on the free-page watermark:
        the pool must cover the prompt plus the lane's FIRST superstep
        (``sync_every`` blocks of K+1 eager tokens, budget-capped) — lanes
        can be admitted after this tick's growth pass ran, so admission
        itself must provision the horizon; later growth is on demand.
        `reserve`: extra pages kept free on top of the watermark
        (pre-admission passes the live lanes' growth demand)."""
        tr = self.telem.tracer
        while self._tq and not all(s is not None for s in self._slots):
            t_a0 = self.clock()
            slot = next(i for i, s in enumerate(self._slots) if s is None)
            req = self._tq.peek()
            if req is None:
                break
            hq = self._handles.get(req.uid)
            if hq is not None and hq.cancel_requested:
                # cancelled while queued: finalize instead of admitting —
                # no lane, no pages, no prefill compute ever spent
                self._tq.take(req)
                self._finish_cancelled_queued(req.uid)
                continue
            max_new = min(req.max_new, self.max_new)
            gen_carry = len(self._preempted.get(req.uid, (None, ()))[1])
            prompt = self._trim_prompt(req, max_new - gen_carry)
            c1 = self._first_chunk(prompt)
            chunked = c1 < len(prompt) - 1   # rest scheduled tick-by-tick
            if self._cache is None:
                self._cache = (self.model.init_paged_cache(
                    self.num_slots, self.kv_pages, self.kv_page_size,
                    self._mps) if self.paged
                    else self.model.init_cache(self.num_slots, self._cap))
            hit = None
            if self.paged and self.prefix_cache:
                # longest cached prefix of the prompt (the pending token is
                # never cached).  Counted per LOOKUP — a watermark-blocked
                # admission retried next tick counts again, by design.
                hit = self._pool.acquire_prefix(req.uid, prompt[:-1])
                self.stats["prefix_lookups"] += 1
                if hit.hit_tokens > 0:
                    self.stats["prefix_hits"] += 1
                    self.stats["prefix_hit_tokens"] += hit.hit_tokens
                else:
                    self.stats["prefix_misses"] += 1
            if hit is not None and hit.hit_tokens > 0:
                # ---- warm admission: splice shared pages, prefill only the
                # uncached tail.  `warm` tokens are already resident (full
                # shared pages + a COW-copied partial page); the tail rides
                # the chunked-prefill path from position `warm`.
                warm = hit.hit_tokens
                tail = len(prompt) - 1 - warm
                need = (self._pool.pages_for(warm + min(self._chunk, tail))
                        if tail > 0
                        else self._pages_needed(len(prompt) - 1,
                                                max_new - gen_carry))
                if not self._pool.can_alloc(need - len(hit.pages),
                                            self.kv_watermark + reserve):
                    if hit.pages:            # put the shared pages back
                        self._pool.free(req.uid)
                    self.telem.c_watermark.inc()
                    if tr is not None:
                        tr.instant(self.telem.tid_engine, "pool_watermark",
                                   args={"uid": req.uid, "need": need,
                                         "free": self._pool.available_pages,
                                         "reserve": reserve})
                    break
                self._tq.take(req)
                fresh = self._pool.ensure(req.uid, need) or []
                cow_dst = fresh[0] if hit.cow_tokens else 0
                if hit.cow_tokens:
                    self.stats["prefix_cow_copies"] += 1
                owned = self._pool.owned(req.uid)
                row = np.full(self._mps, -1, np.int32)
                row[:len(owned)] = owned
                self._tbl_host[slot] = row
                self._pending, self._cache = self._admit_prefix_fn(
                    self._cache, self._pending, jnp.int32(slot),
                    jnp.asarray(row), jnp.int32(warm),
                    jnp.int32(hit.cow_page), jnp.int32(cow_dst),
                    jnp.asarray(prompt[-1]), jnp.asarray(tail == 0))
                c1, chunked = warm, tail > 0
                if not chunked:   # fully cached: nothing new to publish
                    self._pool.publish_prefix(req.uid, prompt[:-1])
            elif self.paged:
                # mid-prefill lanes only hold pages for what is actually
                # cached so far; the rest is provisioned chunk-by-chunk by
                # _advance_prefill (growth-class: like decode page growth
                # it may dip into the admission watermark's headroom)
                need = (self._pool.pages_for(c1) if chunked
                        else self._pages_needed(c1, max_new - gen_carry))
                if not self._pool.can_alloc(need,
                                            self.kv_watermark + reserve):
                    # head-of-line wait for pages (watermark/reserve hit)
                    self.telem.c_watermark.inc()
                    if tr is not None:
                        tr.instant(self.telem.tid_engine, "pool_watermark",
                                   args={"uid": req.uid, "need": need,
                                         "free": self._pool.free_pages,
                                         "reserve": reserve})
                    break
                self._tq.take(req)
                pages = self._pool.alloc(need, owner=req.uid)
                row = np.full(self._mps, -1, np.int32)
                row[:len(pages)] = pages
                self._tbl_host[slot] = row
                # chunked: prefill just prompt[:c1]; the pending it sets is
                # a placeholder, rewritten in-graph by the finishing chunk
                self._pending, self._cache = self._admit_paged_fn(
                    self.params, self._cache, self._pending,
                    jnp.asarray(prompt[:c1 + 1]), jnp.int32(slot),
                    jnp.asarray(row))
                # one-shot cold admission caches the whole prompt prefix in
                # one go — publish it for the next tenant immediately
                if self.prefix_cache and not chunked:
                    self._pool.publish_prefix(req.uid, prompt[:-1])
            else:
                self._tq.take(req)
                if chunked:
                    self._cache = self._admit_chunk_fn(
                        self.params, self._cache, jnp.asarray(prompt[:c1]),
                        jnp.int32(slot))
                else:
                    self._pending, self._cache = self._admit_fn(
                        self.params, self._cache, self._pending,
                        jnp.asarray(prompt), jnp.int32(slot))
            orig_prompt, gen0, blocks0, wall0, seq0 = self._preempted.pop(
                req.uid, (prompt, [], 0, 0.0, None))
            if seq0 is None:             # fresh request; replays keep their
                self._admit_seq += 1     # original admission seniority
                seq0 = self._admit_seq
            self._slots[slot] = _Slot(uid=req.uid, prompt=orig_prompt,
                                      max_new=max_new, gen=list(gen0),
                                      blocks=blocks0, wall_s=wall0,
                                      cache_len=c1,
                                      admit_seq=seq0,
                                      pf_prompt=prompt if chunked else None,
                                      pf_pos=c1 if chunked else None,
                                      handle=hq)
            t_adm = self.clock()
            if hq is not None:
                if hq.t_admit is None:   # FIRST admission only: a preempted
                    hq.t_admit = t_adm   # replay keeps its original wait
                    self.telem.h_queue_wait.observe(
                        t_adm - (hq.t_submit
                                 if hq.t_submit is not None else t_adm))
                if not chunked and hq.t_prefill_done is None:
                    hq.t_prefill_done = t_adm
            # fresh depth-controller state for the recycled lane: a request
            # must not inherit the previous occupant's throttled depth (or a
            # preempted replay its own pre-preemption EMA — prefix replay
            # changes positions, so stale state is not evidence)
            if self._depth is not None:
                self._k_host[slot] = self._depth.k_init
                self._ema_host[slot] = self._depth.ema_init
                self._cool_host[slot] = 0
            # a mid-prefill lane stays done-masked: it rides supersteps
            # inert until its finishing chunk flips it live
            self._done[slot] = chunked
            if tr is not None:
                now = self.clock()
                tr.span(slot, f"admit u{req.uid}", t_a0, now,
                        args={"uid": req.uid, "chunked": chunked,
                              "prefilled": c1})
                tr.async_end("queued", req.uid, now)
                tr.async_begin("prefill", req.uid, now,
                               args={"slot": slot, "chunked": chunked})
                if not chunked:    # one-shot: lane decodes from this tick
                    tr.async_end("prefill", req.uid, now)
                    tr.async_begin("decode", req.uid, now,
                                   args={"slot": slot})

    def _preempt(self, slot: int) -> None:
        """Evict lane `slot` mid-decode: free its pages, unmap its row, and
        re-queue its progress (prompt + generated prefix) at the FRONT of
        the FIFO.  Re-admission replays the prefix via prefill — the same
        tokens at the same positions produce the same KV, so greedy decoding
        continues exactly where it stopped."""
        st = self._slots[slot]
        self._pool.free(st.uid)
        self._tbl_host[slot] = -1
        # carry progress, cost attribution (blocks, wall) AND admission
        # seniority across the preemption: re-admission must not make the
        # victim the "newest" lane again, or two starved lanes ping-pong
        # preempt each other forever — preserving admit_seq makes the
        # globally oldest request strictly win every victim contest, so it
        # always progresses and the system cannot livelock
        self._preempted[st.uid] = (st.prompt, list(st.gen), st.blocks,
                                   st.wall_s, st.admit_seq)
        combined = np.concatenate(
            [st.prompt, np.asarray(st.gen, np.int32)]).astype(np.int32)
        # replays bypass fairness AND the max_queue bound: the request was
        # already admitted once; rejecting or re-queuing it fairly would
        # discard committed work / break the preemption no-livelock argument
        self._tq.push_front(Request(
            uid=st.uid, prompt=combined, max_new=st.max_new,
            tenant=st.handle.tenant if st.handle is not None else "default",
            priority=st.handle.priority if st.handle is not None else 0))
        self._cache = self._reset_fn(self._cache, jnp.int32(slot))
        tr = self.telem.tracer
        if tr is not None:
            now = self.clock()
            tr.instant(slot, "preempt", now,
                       args={"uid": st.uid, "gen_len": len(st.gen),
                             "mid_prefill": st.pf_pos is not None})
            tr.async_end("prefill" if st.pf_pos is not None else "decode",
                         st.uid, now, args={"preempted": True})
            tr.async_begin("queued", st.uid, now, args={"replay": True})
        self._slots[slot] = None
        self._done[slot] = True
        self.stats["preemptions"] += 1

    def _grow_pages(self) -> None:
        """Top every live lane up to the page capacity the NEXT superstep
        can touch: ``sync_every`` blocks each write K+1 eager tokens, so the
        horizon is ``sync_every * (K+1)`` slots — capped by the lane's
        remaining ``max_new`` budget (a lane that can only run r more blocks
        before retiring advances the cache at most r+K slots; growing it
        further would waste pool headroom under pressure).  Adaptive depth
        makes K per-lane: growth sizes each lane for its LIVE depth bound
        (``_lane_growth_k``) instead of the global worst case, so throttled
        low-acceptance lanes release pool headroom to lanes that can
        actually use it.  On pool
        exhaustion, preempt the NEWEST other lane and retry — oldest
        requests keep their pages (no livelock: admission guarantees any
        single request fits the pool).  All row updates of the tick are
        batched into ONE device push (set_block_tables) instead of a
        map_slot_pages dispatch per lane."""
        dirty = False
        for s in sorted((i for i, st in enumerate(self._slots) if st is not None),
                        key=lambda i: self._slots[i].admit_seq):
            st = self._slots[s]
            if st is None or st.pf_pos is not None:
                continue                 # gone, or grown by _advance_prefill
            remaining = st.max_new - len(st.gen)
            if remaining <= 0:           # retires at the next boundary
                continue
            while True:
                got = self._pool.ensure(
                    st.uid, self._pages_needed(st.cache_len, remaining,
                                               k=self._lane_growth_k(s)))
                if got is None:
                    victims = [i for i, v in enumerate(self._slots)
                               if v is not None and i != s]
                    if not victims:      # lone lane: admission sizing makes
                        break            # this unreachable; fail soft
                    self._preempt(max(victims,
                                      key=lambda i: self._slots[i].admit_seq))
                    dirty = True         # preemption unmapped a row
                    continue
                if got:
                    self._sync_row(s, st.uid)
                    dirty = True
                break
        if dirty:
            self._cache = self._set_tbl_fn(self._cache,
                                           jnp.asarray(self._tbl_host))

    def _sync_row(self, s: int, uid: int) -> None:
        """Mirror lane `s`'s pool ownership into the host block table
        (allocation order == logical order); caller batches the device push
        via ``set_block_tables`` once per tick."""
        owned = self._pool.owned(uid)
        self._tbl_host[s] = -1
        self._tbl_host[s, :len(owned)] = owned

    def _advance_prefill(self) -> None:
        """One batched chunk step: every mid-prefill lane advances by up to
        ``prefill_chunk`` prompt tokens, directly in the live cache.  Lanes
        consuming their last prompt token get their pending token set
        in-graph and flip live for THIS tick's superstep.  Paged lanes are
        provisioned incrementally (``KVPool.ensure``) right before the
        chunk's writes land; on exhaustion the newest other lane is
        preempted (oldest-first service, mirroring ``_grow_pages``).
        Per-tick prefill work is bounded: ONE device dispatch covering at
        most ``num_slots * prefill_chunk`` tokens, however long the
        prompts are."""
        lanes = [s for s, st in enumerate(self._slots)
                 if st is not None and st.pf_pos is not None]
        if not lanes:
            return
        B, T = self.num_slots, self._chunk
        tokens = np.zeros((B, T), np.int32)
        take = np.zeros((B,), np.int32)
        finish_tok = np.zeros((B,), np.int32)
        finished = np.zeros((B,), bool)
        dirty = False
        for s in sorted(lanes, key=lambda i: self._slots[i].admit_seq):
            st = self._slots[s]
            if st is None:               # preempted as a victim below
                continue
            tk, fin, extent = self._prefill_extent(st)
            if self.paged:
                while True:
                    got = self._pool.ensure(st.uid,
                                            self._pool.pages_for(extent))
                    if got is not None:
                        break
                    # a starved prefill lane may only evict STRICTLY NEWER
                    # lanes; with none it WAITS a tick instead of evicting a
                    # senior.  Evicting seniors here livelocks: mid-prefill
                    # eviction loses all prefill progress (decode eviction
                    # keeps its generated tokens, which is why _grow_pages
                    # can afford any-victim), so two long prefills sharing a
                    # tight pool would wipe each other forever at the
                    # finish line.  Seniority is a total order, so the
                    # oldest prefill lane can always clear its path, and
                    # admission sizing guarantees it fits the pool alone.
                    victims = [i for i, v in enumerate(self._slots)
                               if v is not None
                               and v.admit_seq > st.admit_seq]
                    if not victims:
                        break
                    v = max(victims, key=lambda i: self._slots[i].admit_seq)
                    self._preempt(v)
                    # victims are strictly newer and this loop runs in
                    # ascending admit_seq order, so v cannot have been
                    # staged yet — these clears are pure defense in case a
                    # future change reorders the loop or widens victimhood
                    tokens[v] = 0
                    take[v] = 0
                    finished[v] = False
                    dirty = True
                if got is None:
                    continue             # starved: retry next tick
                if got:
                    self._sync_row(s, st.uid)
                    dirty = True
            tokens[s, :tk] = st.pf_prompt[st.pf_pos:st.pf_pos + tk]
            take[s] = tk
            if fin:
                finished[s] = True
                finish_tok[s] = st.pf_prompt[-1]
        if dirty:
            self._cache = self._set_tbl_fn(self._cache,
                                           jnp.asarray(self._tbl_host))
        if not take.any() and not finished.any():
            return
        t_c0 = self.clock()
        self._pending, self._cache = self._chunk_fn(
            self.params, self._cache, self._pending, jnp.asarray(tokens),
            jnp.asarray(take), jnp.asarray(finish_tok), jnp.asarray(finished))
        t_c1 = self.clock()
        tick_tokens = int(take.sum())
        self.stats["prefill_chunks"] += 1
        self.stats["prefill_tokens"] += tick_tokens
        self.stats["max_tick_prefill_tokens"] = max(
            self.stats["max_tick_prefill_tokens"], tick_tokens)
        tr = self.telem.tracer
        for s in lanes:
            st = self._slots[s]
            if st is None or (not take[s] and not finished[s]):
                continue
            st.pf_pos += int(take[s])
            st.cache_len += int(take[s])
            if tr is not None:
                tr.span(s, "prefill_chunk", t_c0, t_c1,
                        args={"uid": st.uid, "tokens": int(take[s]),
                              "pos": int(st.pf_pos)})
            if finished[s]:
                # the whole prompt prefix is committed in-cache now — make
                # it hittable for the next tenant sharing it
                if self.paged and self.prefix_cache:
                    self._pool.publish_prefix(st.uid, st.pf_prompt[:-1])
                st.pf_pos = None
                st.pf_prompt = None
                self._done[s] = False
                if st.handle is not None and st.handle.t_prefill_done is None:
                    st.handle.t_prefill_done = t_c1
                if tr is not None:
                    tr.async_end("prefill", st.uid, t_c1)
                    tr.async_begin("decode", st.uid, t_c1,
                                   args={"slot": s})

    def _maybe_profile_start(self):
        """Optional ``jax.profiler`` capture window (``profile_dir``): start
        at the first dispatch, annotate every dispatch as a step, stop after
        ``profile_steps`` dispatches.  Best-effort — profiler failures never
        take down serving."""
        if self.profile_dir and not self._profile_active:
            try:
                jax.profiler.start_trace(self.profile_dir)
                self._profile_active = True
                self._profile_left = max(1, int(self.profile_steps))
            except Exception:
                self.profile_dir = None
        if not self._profile_active:
            return None
        try:
            return jax.profiler.StepTraceAnnotation(
                "superstep", step_num=int(self.stats["dispatches"]))
        except Exception:
            return None

    def _maybe_profile_stop(self) -> None:
        if not self._profile_active:
            return
        self._profile_left -= 1
        if self._profile_left <= 0:
            try:
                jax.profiler.stop_trace()
            except Exception:
                pass
            self._profile_active = False
            self.profile_dir = None     # window consumed; do not restart

    def _dispatch_superstep(self) -> None:
        """Dispatch one fused superstep over the live lanes and return
        immediately — the host does NOT wait for the result (``_harvest``
        does, one engine tick later)."""
        budget = np.ones((self.num_slots,), np.int32)
        for s, st in enumerate(self._slots):
            if st is not None:
                budget[s] = st.max_new - len(st.gen)
        ann = self._maybe_profile_start()
        if ann is not None:
            ann.__enter__()
        if self._depth is not None:
            # per-lane depth ceiling = what growth provisioned pages for;
            # the draft-scan width K_blk is the max ceiling over lanes that
            # can decode this superstep (mid-prefill lanes cannot flip live
            # mid-superstep — _advance_prefill already ran — and free lanes
            # are admitted only at boundaries, so the max over decode lanes
            # is exact, not heuristic)
            kcap = np.full((self.num_slots,), self._k_worst, np.int32)
            kblk = self._depth.k_min
            for s, st in enumerate(self._slots):
                if st is not None and st.pf_pos is None:
                    kcap[s] = self._lane_growth_k(s)
                    kblk = max(kblk, int(kcap[s]))
            res = self._superstep_adaptive_fn(
                self.params, self.state.dvi_params, self._pending,
                self._cache, self.state.buf, jnp.asarray(self._done),
                jnp.asarray(budget), jnp.asarray(self._k_host),
                jnp.asarray(self._ema_host), jnp.asarray(self._cool_host),
                jnp.asarray(kcap), kblk)
        else:
            res = self._superstep_fn(self.params, self.state.dvi_params,
                                     self._pending, self._cache,
                                     self.state.buf, jnp.asarray(self._done),
                                     jnp.asarray(budget))
        if ann is not None:
            ann.__exit__(None, None, None)
        self._maybe_profile_stop()
        # engine state advances to the (not yet materialized) outputs; every
        # follow-up device op (admission, reset, next superstep) chains on
        # them without a host round-trip
        self._pending, self._cache = res.pending, res.cache
        self.state.buf = res.buffer
        lanes = [s for s, st in enumerate(self._slots) if st is not None]
        now = self.clock()
        mark = self._clock + (now - self._tick_t0)
        self._inflight = (res, mark, lanes, now)
        self.stats["dispatches"] += 1
        self.stats["peak_live_slots"] = max(self.stats["peak_live_slots"],
                                            len(lanes))

    def _harvest(self) -> List[Completion]:
        """Materialize the in-flight superstep's compact summary (the ONLY
        device->host sync on the continuous hot path), fold it into host
        bookkeeping, retire finished lanes, and manage drafter updates.

        Telemetry rides this same single ``device_get``: the in-graph
        per-block histograms travel with the summary, and a folded drafter
        update's loss metrics are materialized one harvest LATER (by then
        the superstep that consumed the new params has completed, so the
        update must have too — reading its metrics cannot block)."""
        # fold a completed drafter update FIRST — even with no in-flight
        # superstep (engine drained and is being stepped again), so a
        # trained update dispatched on the last tick of a burst is never
        # dropped; the next dispatch below then uses the fresh params
        tr = self.telem.tracer
        fold_note = None
        if self._update_inflight is not None:
            (self.state.dvi_params, self.state.opt_state,
             self.state.baseline, m_dev, t_disp_u, step_u) = \
                self._update_inflight
            self._update_inflight = None
            t_fold = self.clock()
            # update "latency" = dispatch -> fold staleness window (how long
            # the engine decoded on the pre-update drafter), a host quantity
            self.telem.h_update_span.observe(t_fold - t_disp_u)
            if tr is not None:
                tr.span(self.telem.tid_train, f"drafter_update t{step_u}",
                        t_disp_u, t_fold, args={"step": step_u}, cat="train")
            fold_note = (m_dev, t_disp_u, t_fold, step_u)
        if self._inflight is None:
            if fold_note is not None:
                self._train_staged = fold_note
            return []
        res, clock_mark, lanes, t_disp_wall = self._inflight
        self._inflight = None
        staged = self._train_staged
        t0 = self.clock()
        main, m_host = jax.device_get((
            (res.done, res.gen_count, res.gen_buf, res.lane_blocks,
             res.lane_committed, res.lane_accepted, res.lane_drafted,
             res.k_lane, res.accept_ema, res.k_cool,
             res.accept_hist, res.depth_hist, res.buffer["count"]),
            staged[0] if staged is not None else None))
        (done_np, cnt_np, gen_np, blocks_np, committed_np, accepted_np,
         drafted_np, k_np, ema_np, cool_np, ahist_np, dhist_np,
         buf_count) = main
        now = self.clock()
        self.stats["host_syncs"] += 1
        self.stats["sync_wait_s"] += now - t0
        self.telem.h_sync_wait.observe(now - t0)
        if tr is not None:
            tr.span(self.telem.tid_engine, "sync_wait", t0, now)
        if staged is not None:
            self._fold_train_metrics(m_host, staged[1], staged[2], staged[3])
            self._train_staged = None
        # fold the in-graph per-block histograms (length K_blk+1, which may
        # be below k_max+1 when an adaptive dispatch specialized shallower)
        for i, n in enumerate(ahist_np):
            self.telem.h_block_accept.add(int(i), int(n))
        for i, n in enumerate(dhist_np):
            self.telem.h_block_depth.add(int(i), int(n))
        # iterations the superstep actually executed (it exits early once
        # every lane is done): the longest-lived lane saw all of them
        self.stats["steps"] += int(blocks_np.max(initial=0))
        # engine-resident time since the dispatch (caller time excluded)
        wall = self._clock + (now - self._tick_t0) - clock_mark
        total_blocks = int(blocks_np.sum())
        wall_share = wall / max(total_blocks, 1)

        outs: List[Completion] = []
        k_seen: List[int] = []
        for s in lanes:                  # only lanes occupied at dispatch:
            st = self._slots[s]          # slots admitted since then (into
            if st is None:               # previously-free lanes) rode along
                continue                 # masked done and carry no results
            if st.pf_pos is not None:    # mid-prefill at dispatch: rode the
                continue                 # superstep masked done — NOT done
            nb = int(blocks_np[s])
            st.blocks += nb
            st.wall_s += wall_share * nb
            st.cache_len += int(committed_np[s])
            st.gen.extend(int(t) for t in gen_np[s, :int(cnt_np[s])])
            if st.handle is not None and int(cnt_np[s]) > 0:
                # stream the freshly committed chunk to the handle NOW (the
                # superstep boundary) — consumers see tokens per harvest,
                # not per completion; feed is monotone so replays are safe
                first = st.handle.t_first_token is None
                st.handle.feed(st.gen)
                if first and st.handle.t_first_token is not None:
                    self.telem.h_ttft.observe(
                        st.handle.t_first_token
                        - (st.handle.t_submit
                           if st.handle.t_submit is not None
                           else st.handle.t_first_token))
            self.stats["blocks"] += nb
            self.stats["committed"] += int(committed_np[s])
            self.stats["accepted"] += int(accepted_np[s])
            # EXACT draft accounting, counted in-graph: sum of the depth
            # each LIVE block actually ran at (a lane that went done early
            # rides the rest of the superstep without inflating its drafts;
            # an adaptive lane counts its per-block k, not the global K)
            self.stats["drafted"] += int(drafted_np[s])
            self._slot_accepted[s] += int(accepted_np[s])
            self._slot_drafted[s] += int(drafted_np[s])
            self._slot_committed[s] += int(committed_np[s])
            self._slot_blocks[s] += nb
            k_seen.append(int(k_np[s]))
            if tr is not None:
                tr.span(s, "superstep", t_disp_wall, now,
                        args={"uid": st.uid, "blocks": nb,
                              "committed": int(committed_np[s]),
                              "accepted": int(accepted_np[s]),
                              "k": int(k_np[s])})
                if self._depth is not None and \
                        int(k_np[s]) != int(self._k_host[s]):
                    tr.instant(
                        s, f"depth {int(self._k_host[s])}->{int(k_np[s])}",
                        now, args={"uid": st.uid, "ema": float(ema_np[s])})
            # fold the lane's post-superstep controller state into the host
            # mirror (masked lanes came back unchanged, so this is exact)
            if self._depth is not None:
                self._k_host[s] = k_np[s]
                self._ema_host[s] = ema_np[s]
                self._cool_host[s] = cool_np[s]
            if done_np[s]:               # EOS or budget, detected in-graph
                gen = np.asarray(st.gen, np.int32)
                comp = self._complete(
                    st.uid, np.concatenate([st.prompt, gen]), gen,
                    len(st.gen) / max(st.blocks, 1), st.wall_s)
                outs.append(comp)
                self._finish_handle(st.uid, comp)
                self.stats["requests"] += 1
                if self.paged:
                    self._pool.free(st.uid)   # copy-free eviction: pages
                    self._tbl_host[s] = -1    # recycle host-side
                self._cache = self._reset_fn(self._cache, jnp.int32(s))
                self._slots[s] = None
                self._done[s] = True

        if k_seen:
            km = float(np.mean(k_seen))
            self.stats["k_mean"].append(km)
            self.telem.g_depth_mean.set(km)

        # drafter update cadence: maybe dispatch the next update — WITHOUT
        # blocking on it; the engine decodes one superstep on stale
        # dvi_params while the optimizer runs (folded at the top of the
        # next harvest, i.e. the next superstep boundary)
        self._blocks_since_update += int(blocks_np.max(initial=0))
        if (self.learn and self._blocks_since_update >= self.update_every
                and int(buf_count) > 0):
            self._blocks_since_update = 0
            t_disp_u = self.clock()
            step_u = self._step_host
            self._key, sub = jax.random.split(self._key)
            new_dvi, new_opt, new_base, m_dev = self._update_fn(
                self.params, self.state.dvi_params, self.state.opt_state,
                self.state.buf, self.state.baseline, self.state.step, sub)
            self._update_inflight = (new_dvi, new_opt, new_base, m_dev,
                                     t_disp_u, step_u)
            self.state.step = self.state.step + 1
            self.stats["updates"] += 1
            self._note_update_dispatched()
            self.telem.g_buffer.set(int(buf_count))
            if tr is not None:
                tr.instant(self.telem.tid_train, "update_dispatch", t_disp_u,
                           args={"step": step_u, "buffer": int(buf_count)},
                           cat="train")
        if fold_note is not None:
            self._train_staged = fold_note
        return outs

    def _step_continuous(self) -> List[Completion]:
        """One tick: pre-admit arrivals into already-free lanes (their
        prefill dispatches queue behind the in-flight superstep — host work
        overlaps device compute), harvest the in-flight superstep, retire
        finished lanes, grow paged lanes (preempting if the pool runs dry),
        admit into freshly freed lanes, advance mid-prefill lanes by one
        chunk, and dispatch the next superstep."""
        self._tick_t0 = tick0 = self.clock()
        tr = self.telem.tracer
        tid_e = self.telem.tid_engine if tr is not None else 0

        def _phase(name, fn, *a):
            if tr is None:
                return fn(*a)
            p0 = self.clock()
            try:
                return fn(*a)
            finally:
                tr.span(tid_e, name, p0, self.clock())

        try:
            # pre-admission reserves the live lanes' worst-case growth
            # demand (paged): a new request must not grab pages this tick's
            # growth pass would claw back by preempting the admitted lane
            _phase("pre_admit", self._admit_waiting,
                   self._growth_reserve() if self.paged else 0)
            outs = _phase("harvest", self._harvest)
            # cancellation boundary: the harvest just retired the in-flight
            # superstep, so lanes can be torn down without racing device
            # reads of their pages; queued cancels drop out of the tenant
            # queue before this tick's growth/admission see them
            _phase("sweep_cancels", self._sweep_cancels)
            # grow BEFORE admitting: admission then sees the true residual
            # capacity, instead of grabbing pages that live lanes
            # immediately claw back by preempting the just-admitted lane.
            # Mid-prefill lanes' imminent chunk demand stays reserved even
            # here: _advance_prefill consumes it right after this admission.
            if self.paged:
                _phase("grow_pages", self._grow_pages)
            _phase("admit", self._admit_waiting,
                   self._prefill_reserve() if self.paged else 0)
            # chunked prefill interleaves with supersteps: one bounded
            # chunk step per tick, then the superstep over decoding lanes
            # (lanes whose prefill finished this tick included)
            _phase("prefill_chunk", self._advance_prefill)
            if any(st is not None and st.pf_pos is None
                   for st in self._slots):
                _phase("dispatch", self._dispatch_superstep)
        finally:
            dt = self.clock() - self._tick_t0
            self._clock += dt
            self.stats["tick_s"].append(dt)
            self.telem.h_tick.observe(dt)
            t = self.telem
            t.g_live.set(self.active_slots)
            t.g_queue.set(len(self._tq))
            if self.paged:
                # free counts evictable cached pages — what admission may
                # actually use; g_kv_cached breaks out the warm subset
                t.g_kv_used.set(self._pool.used_pages)
                t.g_kv_free.set(self._pool.available_pages)
                t.g_kv_cached.set(self._pool.cached_pages)
                ev = self._pool.evictions
                if ev != self._evict_seen:
                    self.stats["prefix_evictions"] += ev - self._evict_seen
                    self._evict_seen = ev
            if tr is not None:
                tr.span(tid_e, "tick", tick0, tick0 + dt,
                        args={"live": self.active_slots,
                              "queued": len(self._tq)})
            self._tick_t0 = None
        return outs

    # ------------------------------------------------------------------
    # driver
    # ------------------------------------------------------------------

    def step(self) -> List[Completion]:
        if self.scheduler == "continuous":
            return self._step_continuous()
        return self._step_sync()

    @property
    def busy(self) -> bool:
        # _update_inflight keeps the engine busy so the driver steps once
        # more and the final drafter update of a burst is actually folded;
        # queued-but-cancelled requests keep _tq non-empty until the sweep
        # finalizes them, so the stepping loop is guaranteed to reach them
        return (bool(self._tq) or self.active_slots > 0
                or self._inflight is not None
                or self._update_inflight is not None
                or any(self._queue.values()))

    def run(self, max_steps: int = 10**9) -> List[Completion]:
        done: List[Completion] = []
        for _ in range(max_steps):
            if not self.busy:
                break
            done.extend(self.step())
        return done

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------

    def reset_stats(self) -> None:
        """Zero every registry metric, rolling window, and per-slot counter
        (e.g. after a warm-up run); jit caches, drafter state, and live
        slots are untouched.  The key set comes from the ONE canonical
        schema (``telemetry.LEGACY_STATS`` + the registry declarations), so
        it can never drift from the live stats view."""
        self.telem.registry.reset()
        self.stats.reset()           # registry metrics again (idempotent)
        self.train_history.clear()   # + the deques behind the facade
        self._slot_accepted[:] = 0
        self._slot_drafted[:] = 0
        self._slot_committed[:] = 0
        self._slot_blocks[:] = 0

    def _fold_train_metrics(self, m: dict, t_disp: float, t_fold: float,
                            step_u: int) -> None:
        """Publish one materialized drafter-update metrics dict (already on
        host) into the ``dvi_train_*`` gauges + the bounded history."""
        t = self.telem

        def g(key):
            return float(m[key]) if key in m else 0.0

        t.g_loss.set(g("loss"))
        t.g_loss_kl.set(g("kl"))
        t.g_loss_ce.set(g("l_pg"))       # reward-masked CE component
        t.g_loss_pg.set(g("pg_on"))      # on-policy policy-gradient term
        t.g_lambda_pg.set(g("lam_pg"))
        t.g_lambda_kl.set(g("lam_kl"))
        t.g_beta.set(g("beta"))
        t.g_acc_batch.set(g("acc_rate"))
        t.g_ema_before.set(g("baseline_before"))
        t.g_ema_after.set(g("baseline_after"))
        t.g_buffer.set(g("buffer_count"))
        t.g_gnorm.set(g("gnorm"))
        self.train_history.append({
            "step": step_u,
            "phase": schedule_mod.phase_info(
                step_u, self.model.cfg.dvi)["phase"],
            "loss": g("loss"), "loss_kl": g("kl"), "loss_ce": g("l_pg"),
            "loss_pg": g("pg_on"), "acceptance_batch": g("acc_rate"),
            "ema_before": g("baseline_before"),
            "ema_after": g("baseline_after"),
            "buffer_count": g("buffer_count"),
            "span_s": t_fold - t_disp})

    def train_telemetry(self) -> dict:
        """DVI training-loop telemetry: schedule phase, per-component
        losses, acceptance EMA around updates, plus the bounded per-update
        ``history``.  Materializes any still-staged update metrics — may
        synchronize with the device, so call OFF the serving hot path
        (between bursts, at shutdown, in benches)."""
        if self._train_staged is not None:
            m_dev, t_disp, t_fold, step_u = self._train_staged
            self._train_staged = None
            self._fold_train_metrics(jax.device_get(m_dev), t_disp, t_fold,
                                     step_u)
        t = self.telem
        ph = schedule_mod.phase_info(self._step_host, self.model.cfg.dvi)
        return {
            "updates": int(self.stats["updates"]),
            "step": self._step_host,
            "phase": ph["phase"], "phase_name": ph["phase_name"],
            "lambda_pg": ph["lambda_pg"], "lambda_kl": ph["lambda_kl"],
            "beta": ph["beta"],
            "loss": t.g_loss.value, "loss_kl": t.g_loss_kl.value,
            "loss_ce": t.g_loss_ce.value, "loss_pg": t.g_loss_pg.value,
            "acceptance_batch": t.g_acc_batch.value,
            "acceptance_ema_before": t.g_ema_before.value,
            "acceptance_ema_after": t.g_ema_after.value,
            "buffer_count": t.g_buffer.value,
            "history": list(self.train_history),
        }

    def metrics_snapshot(self) -> dict:
        """JSON-able snapshot of every registry metric (see telemetry.py
        for the schema reference)."""
        return self.telem.snapshot()

    def render_prometheus(self) -> str:
        return self.telem.render_prometheus()

    def write_metrics(self, path: str) -> None:
        self.telem.write_metrics(path)

    def trace_dict(self) -> Optional[dict]:
        """The Chrome-trace dict (``telemetry=True`` runs only)."""
        tr = self.telem.tracer
        return tr.to_dict() if tr is not None else None

    def write_trace(self, path: str) -> None:
        tr = self.telem.tracer
        if tr is None:
            raise ValueError("tracing is off — construct the engine with "
                             "telemetry=True to record a trace")
        tr.write(path)

    @property
    def acceptance(self) -> float:
        return self.stats["accepted"] / max(self.stats["drafted"], 1)

    @property
    def slot_acceptance(self) -> np.ndarray:
        """(num_slots,) lifetime acceptance rate per lane."""
        return self._slot_accepted / np.maximum(self._slot_drafted, 1)

    def adaptive_stats(self) -> dict:
        """Depth-controller observability: the current per-slot depth /
        acceptance-EMA, per-slot depth trajectory summaries (mean depth over
        the slot's live blocks), and drafted-vs-committed efficiency — how
        many committed tokens each drafted token bought, the quantity
        adaptive depth exists to raise.  Meaningful (but still reported,
        pinned at k_spec) when ``adaptive_k=False``."""
        drafted = max(self.stats["drafted"], 1)
        recent = list(self.stats["k_mean"])
        return {
            "adaptive": self._depth is not None,
            "k_min": self._depth.k_min if self._depth else
                self.model.cfg.dvi.k_spec,
            "k_max": self._k_worst,
            "k_lane": self._k_host.copy(),
            "accept_ema": self._ema_host.copy(),
            "slot_mean_depth": self._slot_drafted
                / np.maximum(self._slot_blocks, 1),
            "slot_draft_efficiency": self._slot_committed
                / np.maximum(self._slot_drafted, 1),
            "mean_depth": self.stats["drafted"]
                / max(self.stats["blocks"], 1),
            "draft_efficiency": self.stats["committed"] / drafted,
            "k_mean_recent": float(np.mean(recent)) if recent else 0.0,
        }

    def kv_stats(self) -> dict:
        """Paged-pool observability: utilization / watermark / fragmentation
        plus scheduler-level preemption and concurrency counters."""
        if not self.paged:
            return {"paged": False}
        live_tokens = sum(st.cache_len for st in self._slots if st is not None)
        out = self._pool.utilization(live_tokens)
        out.update(paged=True, preemptions=self.stats["preemptions"],
                   peak_live_slots=self.stats["peak_live_slots"])
        return out

    def latency_percentiles(self) -> dict:
        """Percentiles over the most recent ``latency_window`` completions
        (rolling window, so long-running engines stay O(window) memory)."""
        lats = np.asarray(self.stats["latencies"], np.float64)
        if lats.size == 0:
            # well-defined empty result: all-zero percentiles + an explicit
            # count so callers can tell "no completions yet" from "fast"
            return {"p50_s": 0.0, "p95_s": 0.0, "mean_s": 0.0, "count": 0}
        return {"p50_s": float(np.percentile(lats, 50)),
                "p95_s": float(np.percentile(lats, 95)),
                "mean_s": float(np.mean(lats)),
                "count": int(lats.size)}

    def tick_percentiles(self) -> dict:
        """Engine-tick wall-time percentiles over the most recent
        ``latency_window`` ticks — the block-step cadence jitter that
        chunked prefill bounds (a one-shot prefill of a long prompt shows
        up as one fat tick; chunking spreads it)."""
        ts = np.asarray(self.stats["tick_s"], np.float64)
        if ts.size == 0:
            return {"p50_s": 0.0, "p95_s": 0.0, "max_s": 0.0, "count": 0}
        return {"p50_s": float(np.percentile(ts, 50)),
                "p95_s": float(np.percentile(ts, 95)),
                "max_s": float(ts.max()),
                "count": int(ts.size)}

    def dispatch_stats(self) -> dict:
        """Host/device interplay on the continuous hot path: how often the
        host synced with the device, how long it sat blocked, and how many
        superstep dispatches covered the executed block-steps.  `steps` is
        scheduler ITERATIONS (batch block-steps executed); `blocks` in
        `stats` is the per-live-lane count used for MAT/acceptance."""
        steps = max(self.stats["steps"], 1)
        return {
            "sync_every": self.sync_every,
            "steps": self.stats["steps"],
            "dispatches": self.stats["dispatches"],
            "host_syncs": self.stats["host_syncs"],
            "host_syncs_per_100_blocks":
                100.0 * self.stats["host_syncs"] / steps,
            "host_wait_s": self.stats["sync_wait_s"],
            "prefill_chunk": self._chunk,
            "prefill_chunks": self.stats["prefill_chunks"],
            "prefill_tokens": self.stats["prefill_tokens"],
            "max_tick_prefill_tokens":
                self.stats["max_tick_prefill_tokens"],
        }
