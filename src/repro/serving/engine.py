"""Continual-learning serving engine: slot-scheduled continuous batching.

The paper's deployment story — one model serving live traffic while every
verify step trains the drafter — implemented as a **slot scheduler** around
the shared speculative block-step (``spec_block_step``):

* the decode batch is a fixed set of ``num_slots`` lanes over one persistent
  cache; each lane independently holds a request at its own committed length,
* arriving requests are prefilled individually (exact prompt, no bucket
  padding) and spliced into a free lane with ``transformer.insert_slot``,
* every engine tick runs ONE speculative block across all lanes; idle lanes
  ride along masked ``done`` (accept = 0, no state change, no tuples logged),
* lanes retire per-request on EOS or ``max_new`` — completions stream out as
  they finish instead of waiting for the whole batch (no head-of-line
  blocking) — and the lane is reset (``transformer.reset_slot``) for reuse,
* the LoRA drafter takes an update every ``update_every`` block-steps from
  the replay buffer, decoupled from request boundaries,
* per-request latency (arrival -> completion; see ``latency_percentiles``)
  and per-slot acceptance are tracked so drift and stragglers are observable.

With ``kv_pages > 0`` the continuous scheduler runs over a **paged** KV
cache (``repro.serving.kv_pool``): full-attention KV lives in a shared page
pool, lanes hold block-table rows instead of worst-case contiguous regions,
and scheduling becomes memory-aware:

* **admission** checks the free-page watermark, not just a free lane — a
  request is admitted when the pool can cover its prompt plus one
  speculative block (later growth is on demand),
* **growth**: before every block-step each live lane is topped up to cover
  ``length + K + 2`` slots; pages are allocated only as sequences grow, so
  short requests no longer pay for long ones,
* **preempt-or-queue**: when the pool runs dry mid-decode, the newest lane
  is preempted — its pages return to the pool, its progress (prompt +
  generated prefix) is re-queued at the front of the FIFO and replayed via
  prefill on re-admission, which is lossless for greedy decoding,
* retirement frees the lane's pages (``reset_slot`` just unmaps the
  block-table row; no KV bytes move).

``scheduler="sync"`` keeps the legacy batch-synchronous path (bucket by
prompt length, decode a whole batch to completion with
``speculative_generate``) for comparison — ``benchmarks/serving_bench.py``
races the two on the same Poisson arrival trace.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import online as online_mod
from repro.core import spec as spec_mod
from repro.models import transformer as tfm
from repro.models.model import Model
from repro.serving.kv_pool import KVPool


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (Tp,) int32
    max_new: int = 64


@dataclass
class Completion:
    uid: int
    tokens: np.ndarray            # full stream (prompt + generated)
    gen_tokens: np.ndarray        # generated tokens only
    mat: float                    # mean accepted tokens/block for this request
    wall_s: float                 # engine time attributed to this request
    latency_s: float = 0.0        # submit -> completion wall time


@dataclass
class _Slot:
    """Host-side bookkeeping for one live lane of the decode batch."""
    uid: int
    prompt: np.ndarray
    max_new: int
    gen: List[int] = field(default_factory=list)
    blocks: int = 0
    wall_s: float = 0.0
    cache_len: int = 0            # committed cache length (paged growth)
    admit_seq: int = 0            # admission order (paged preemption picks max)


@dataclass
class ServingEngine:
    model: Model
    params: dict
    state: online_mod.OnlineTrainerState
    scheduler: str = "sync"       # "sync" (legacy batch) | "continuous"
    num_slots: int = 8            # continuous: lanes in the decode batch
    batch_size: int = 8           # sync: requests per batch
    max_new: int = 64             # default / cap for generation length
    buckets: tuple = (16, 32, 64, 128)
    updates_per_batch: int = 1    # sync: drafter updates after each batch
    update_every: int = 4         # continuous: blocks between drafter updates
    learn: bool = True
    lr: float = 1e-3
    mode: str = "full"
    eos_id: int = 1
    cache_len: int = 0            # continuous cache capacity (0 = derive)
    kv_pages: int = 0             # >0: paged KV pool with this many pages
    kv_page_size: int = 16        # tokens per page (paged mode)
    kv_watermark: int = 0         # pages kept free at admission (paged mode)
    _queue: Dict[int, List[Request]] = field(default_factory=dict)
    _fifo: deque = field(default_factory=deque)
    stats: dict = field(default_factory=lambda: {
        "requests": 0, "blocks": 0, "committed": 0, "accepted": 0,
        "drafted": 0, "updates": 0, "preemptions": 0, "peak_live_slots": 0,
        "latencies": []})

    def __post_init__(self):
        model, cfg = self.model, self.model.cfg
        K = cfg.dvi.k_spec
        self._cap = self.cache_len or (max(self.buckets) + self.max_new
                                       + K + 2 + tfm.RING_SLACK)
        self._update_fn = online_mod.make_update_fn(self.model, self.mode,
                                                    self.lr)
        self._key = jax.random.PRNGKey(1234)

        # continuous state: one persistent cache, host-side slot table
        self._slots: List[Optional[_Slot]] = [None] * self.num_slots
        self._done = np.ones((self.num_slots,), bool)
        self._pending = jnp.zeros((self.num_slots,), jnp.int32)
        self._cache: Optional[dict] = None
        self._slot_accepted = np.zeros((self.num_slots,), np.int64)
        self._slot_drafted = np.zeros((self.num_slots,), np.int64)
        self._submit_t: Dict[int, float] = {}
        self._blocks_since_update = 0

        # ONE jitted generation entry point (jit shape-specializes on
        # `prompts`, so per-bucket closure caching was pure duplication);
        # max_new is threaded as a static arg, not a Python closure.
        def gen(params, dvi_params, prompts, buf, live, max_new):
            return spec_mod.speculative_generate(
                model, params, dvi_params, prompts, max_new,
                collect=True, buf=buf, live_mask=live)
        self._gen = jax.jit(gen, static_argnums=(5,))

        def block(params, dvi_params, pending, cache, buf, done):
            blk = spec_mod.spec_block_step(model, params, dvi_params,
                                           pending, cache, done=done)
            buf = spec_mod.log_block_tuples(cfg, buf, blk, pending, done)
            return blk.pending, blk.commit_vec, blk.accept, blk.m, blk.cache, buf
        self._block = jax.jit(block)

        cap = self._cap

        # paged KV pool: host-side ownership; block tables live in the cache
        self.paged = self.kv_pages > 0
        self._pool: Optional[KVPool] = None
        self._admit_seq = 0
        self._preempted: Dict[int, tuple] = {}   # uid -> (orig prompt, gen)
        if self.paged:
            if self.scheduler != "continuous":
                raise ValueError("paged KV requires scheduler='continuous'")
            self._pool = KVPool(self.kv_pages, self.kv_page_size)
            self._mps = self._pool.pages_for(cap)      # block-table width
            if self.kv_pages - self.kv_watermark < self._mps:
                raise ValueError(
                    f"kv_pages={self.kv_pages} minus watermark="
                    f"{self.kv_watermark} cannot hold one worst-case request "
                    f"({self._mps} pages of {self.kv_page_size}) — admission "
                    f"would livelock")

        def admit(params, cache, pending, prompt, slot):
            _, pc, _ = model.prefill(params, prompt[None, :-1], max_len=cap)
            cache = tfm.insert_slot(cfg, cache, pc, slot)
            pending = jax.lax.dynamic_update_slice_in_dim(
                pending, prompt[-1:], slot, 0)
            return pending, cache
        self._admit_fn = jax.jit(admit)

        def admit_paged(params, cache, pending, prompt, slot, row):
            cache = tfm.map_slot_pages(cache, slot, row)
            # prefill scratch is prompt-sized, not worst-case-sized: the
            # splice through the block table is what lands it in the pool
            _, pc, _ = model.prefill(params, prompt[None, :-1],
                                     max_len=prompt.shape[0] - 1)
            cache = tfm.insert_slot(cfg, cache, pc, slot)
            pending = jax.lax.dynamic_update_slice_in_dim(
                pending, prompt[-1:], slot, 0)
            return pending, cache
        self._admit_paged_fn = jax.jit(admit_paged)

        self._map_fn = jax.jit(
            lambda cache, slot, row: tfm.map_slot_pages(cache, slot, row))
        self._reset_fn = jax.jit(
            lambda cache, slot: tfm.reset_slot(cfg, cache, slot))

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def submit(self, req: Request) -> None:
        self._submit_t[req.uid] = time.perf_counter()
        if self.scheduler == "continuous":
            self._fifo.append(req)
        else:
            b = self._bucket(len(req.prompt))
            self._queue.setdefault(b, []).append(req)

    def _pad(self, req: Request, bucket: int) -> np.ndarray:
        p = req.prompt[-bucket:]
        if len(p) < bucket:                      # left-pad by repeating BOS
            p = np.concatenate([np.full(bucket - len(p), p[0], p.dtype), p])
        return p

    # ------------------------------------------------------------------
    # drafter updates (shared)
    # ------------------------------------------------------------------

    def _drafter_update(self, n: int) -> None:
        for _ in range(n):
            self._key, sub = jax.random.split(self._key)
            (self.state.dvi_params, self.state.opt_state,
             self.state.baseline, _m) = self._update_fn(
                self.params, self.state.dvi_params, self.state.opt_state,
                self.state.buf, self.state.baseline, self.state.step, sub)
            self.state.step = self.state.step + 1
            self.stats["updates"] += 1

    def _complete(self, uid: int, tokens: np.ndarray, gen_tokens: np.ndarray,
                  mat: float, wall_s: float) -> Completion:
        lat = time.perf_counter() - self._submit_t.pop(uid, time.perf_counter())
        self.stats["latencies"].append(lat)
        return Completion(uid=uid, tokens=tokens, gen_tokens=gen_tokens,
                          mat=mat, wall_s=wall_s, latency_s=lat)

    # ------------------------------------------------------------------
    # sync scheduler (legacy batch path)
    # ------------------------------------------------------------------

    def _step_sync(self) -> List[Completion]:
        """Serve one batch from the fullest bucket; maybe update the drafter."""
        if not any(self._queue.values()):
            return []
        bucket = max(self._queue, key=lambda b: len(self._queue[b]))
        reqs = self._queue[bucket][:self.batch_size]
        self._queue[bucket] = self._queue[bucket][self.batch_size:]
        n_real = len(reqs)
        while len(reqs) < self.batch_size:       # pad batch with replays
            reqs.append(reqs[-1])
        # padded lanes are masked out of generation, tuple logging, and stats
        live = jnp.arange(self.batch_size) < n_real
        prompts = jnp.asarray(np.stack([self._pad(r, bucket) for r in reqs]))

        t0 = time.perf_counter()
        res = self._gen(self.params, self.state.dvi_params, prompts,
                        self.state.buf, live, int(self.max_new))
        jax.block_until_ready(res.tokens)
        wall = time.perf_counter() - t0
        self.state.buf = res.buffer

        if self.learn:
            self._drafter_update(self.updates_per_batch)

        mat = float(res.committed) / max(float(res.blocks), 1.0)
        self.stats["requests"] += n_real
        self.stats["blocks"] += int(res.blocks)
        self.stats["committed"] += int(res.committed)
        self.stats["accepted"] += int(res.accepted_drafts)
        self.stats["drafted"] += int(res.drafted)

        outs = []
        toks = np.asarray(res.tokens)
        lens = np.asarray(res.lengths)
        for i, r in enumerate(reqs[:n_real]):
            # the batch decodes to the engine-wide max_new (head-of-line cost
            # of sync scheduling) but the client only gets what it asked for
            gen = toks[i, bucket:lens[i]][:min(r.max_new, self.max_new)]
            outs.append(self._complete(
                r.uid, np.concatenate([toks[i, :bucket], gen]), gen,
                mat, wall / n_real))
        return outs

    # ------------------------------------------------------------------
    # continuous scheduler (slot-based)
    # ------------------------------------------------------------------

    @property
    def active_slots(self) -> int:
        return sum(s is not None for s in self._slots)

    def _trim_prompt(self, req: Request, remaining_new: int) -> np.ndarray:
        """`remaining_new`: generation budget still outstanding — the full
        max_new for fresh requests, minus tokens already generated for
        re-queued preempted ones (whose prompt carries that prefix, so the
        worst-case capacity check must not double-count it)."""
        cfg = self.model.cfg
        prompt = np.asarray(req.prompt, np.int32)
        if len(prompt) < 2:                  # need prefill + pending
            prompt = np.concatenate(
                [np.full(2 - len(prompt), prompt[0], np.int32), prompt])
        # oversized prompts keep their suffix (mirrors the sync path's
        # `_pad` truncation) rather than crashing the serving loop
        limit = self._cap - remaining_new - cfg.dvi.k_spec - 2
        if len(prompt) > limit:
            prompt = prompt[-limit:]
        return prompt

    def _admit_waiting(self) -> None:
        """Prefill-on-arrival: splice queued requests into free lanes.
        Paged mode additionally gates admission on the free-page watermark:
        the pool must cover the prompt plus one speculative block (decode
        growth is allocated on demand, block by block)."""
        cfg = self.model.cfg
        K = cfg.dvi.k_spec
        while self._fifo and not all(s is not None for s in self._slots):
            slot = next(i for i, s in enumerate(self._slots) if s is None)
            req = self._fifo[0]
            max_new = min(req.max_new, self.max_new)
            gen_carry = len(self._preempted.get(req.uid, (None, ()))[1])
            prompt = self._trim_prompt(req, max_new - gen_carry)
            if self._cache is None:
                self._cache = (self.model.init_paged_cache(
                    self.num_slots, self.kv_pages, self.kv_page_size,
                    self._mps) if self.paged
                    else self.model.init_cache(self.num_slots, self._cap))
            if self.paged:
                need = self._pool.pages_for(len(prompt) + K + 1)
                if not self._pool.can_alloc(need, self.kv_watermark):
                    break                    # head-of-line wait for pages
                self._fifo.popleft()
                pages = self._pool.alloc(need, owner=req.uid)
                row = np.full(self._mps, -1, np.int32)
                row[:len(pages)] = pages
                self._pending, self._cache = self._admit_paged_fn(
                    self.params, self._cache, self._pending,
                    jnp.asarray(prompt), jnp.int32(slot), jnp.asarray(row))
            else:
                self._fifo.popleft()
                self._pending, self._cache = self._admit_fn(
                    self.params, self._cache, self._pending,
                    jnp.asarray(prompt), jnp.int32(slot))
            orig_prompt, gen0, blocks0, wall0 = self._preempted.pop(
                req.uid, (prompt, [], 0, 0.0))
            self._admit_seq += 1
            self._slots[slot] = _Slot(uid=req.uid, prompt=orig_prompt,
                                      max_new=max_new, gen=list(gen0),
                                      blocks=blocks0, wall_s=wall0,
                                      cache_len=len(prompt) - 1,
                                      admit_seq=self._admit_seq)
            self._done[slot] = False

    def _preempt(self, slot: int) -> None:
        """Evict lane `slot` mid-decode: free its pages, unmap its row, and
        re-queue its progress (prompt + generated prefix) at the FRONT of
        the FIFO.  Re-admission replays the prefix via prefill — the same
        tokens at the same positions produce the same KV, so greedy decoding
        continues exactly where it stopped."""
        st = self._slots[slot]
        self._pool.free(st.uid)
        # carry progress AND cost attribution (blocks, wall) across the
        # preemption so Completion.mat / wall_s stay truthful
        self._preempted[st.uid] = (st.prompt, list(st.gen), st.blocks,
                                   st.wall_s)
        combined = np.concatenate(
            [st.prompt, np.asarray(st.gen, np.int32)]).astype(np.int32)
        self._fifo.appendleft(Request(uid=st.uid, prompt=combined,
                                      max_new=st.max_new))
        self._cache = self._reset_fn(self._cache, jnp.int32(slot))
        self._slots[slot] = None
        self._done[slot] = True
        self.stats["preemptions"] += 1

    def _grow_pages(self) -> None:
        """Top every live lane up to `cache_len + K + 2` slots of page
        capacity before the block-step (the draft writes K+1 eager tokens at
        positions len..len+K).  On pool exhaustion, preempt the NEWEST other
        lane and retry — oldest requests keep their pages (no livelock:
        admission guarantees any single request fits the pool)."""
        K = self.model.cfg.dvi.k_spec
        for s in sorted((i for i, st in enumerate(self._slots) if st is not None),
                        key=lambda i: self._slots[i].admit_seq):
            st = self._slots[s]
            if st is None:
                continue
            while True:
                have = len(self._pool.owned(st.uid))
                need = self._pool.pages_for(st.cache_len + K + 2)
                if need <= have:
                    break
                got = self._pool.alloc(need - have, owner=st.uid)
                if got is None:
                    victims = [i for i, v in enumerate(self._slots)
                               if v is not None and i != s]
                    if not victims:      # lone lane: admission sizing makes
                        break            # this unreachable; fail soft
                    self._preempt(max(victims,
                                      key=lambda i: self._slots[i].admit_seq))
                    continue
                row = np.full(self._mps, -1, np.int32)
                owned = self._pool.owned(st.uid)    # allocation order == logical
                row[:len(owned)] = owned
                self._cache = self._map_fn(self._cache, jnp.int32(s),
                                           jnp.asarray(row))

    def _step_continuous(self) -> List[Completion]:
        """One tick: admit arrivals, grow paged lanes (preempting if the
        pool runs dry), run ONE speculative block across all lanes, retire
        finished lanes, maybe update the drafter."""
        # grow BEFORE admitting: admission then sees the true residual
        # capacity, instead of grabbing pages that live lanes immediately
        # claw back by preempting the just-admitted (newest) lane
        if self.paged:
            self._grow_pages()
        self._admit_waiting()
        if self.active_slots == 0:
            return []
        self.stats["peak_live_slots"] = max(self.stats["peak_live_slots"],
                                            self.active_slots)
        K = self.model.cfg.dvi.k_spec
        done = jnp.asarray(self._done)
        t0 = time.perf_counter()
        (self._pending, commit_vec, accept, m, self._cache,
         self.state.buf) = self._block(self.params, self.state.dvi_params,
                                       self._pending, self._cache,
                                       self.state.buf, done)
        jax.block_until_ready(commit_vec)
        wall = time.perf_counter() - t0
        wall_each = wall / self.active_slots
        commit_np = np.asarray(commit_vec)
        acc_np = np.asarray(accept)
        m_np = np.asarray(m)

        outs: List[Completion] = []
        for s, st in enumerate(self._slots):
            if st is None:
                continue
            st.blocks += 1
            st.wall_s += wall_each
            st.cache_len += int(acc_np[s])
            self.stats["blocks"] += 1
            self.stats["committed"] += int(acc_np[s])
            self.stats["accepted"] += int(m_np[s])
            self.stats["drafted"] += K
            self._slot_accepted[s] += int(m_np[s])
            self._slot_drafted[s] += K
            for t in commit_np[s, :int(acc_np[s])]:
                if len(st.gen) >= st.max_new:
                    break
                st.gen.append(int(t))
                if int(t) == self.eos_id:
                    break
            if st.gen and (st.gen[-1] == self.eos_id
                           or len(st.gen) >= st.max_new):
                gen = np.asarray(st.gen, np.int32)
                outs.append(self._complete(
                    st.uid, np.concatenate([st.prompt, gen]), gen,
                    len(st.gen) / max(st.blocks, 1), st.wall_s))
                self.stats["requests"] += 1
                if self.paged:
                    self._pool.free(st.uid)   # copy-free eviction: pages
                self._cache = self._reset_fn(self._cache, jnp.int32(s))
                self._slots[s] = None
                self._done[s] = True

        self._blocks_since_update += 1
        if (self.learn and self._blocks_since_update >= self.update_every
                and int(self.state.buf["count"]) > 0):
            self._blocks_since_update = 0
            self._drafter_update(1)
        return outs

    # ------------------------------------------------------------------
    # driver
    # ------------------------------------------------------------------

    def step(self) -> List[Completion]:
        if self.scheduler == "continuous":
            return self._step_continuous()
        return self._step_sync()

    @property
    def busy(self) -> bool:
        return (bool(self._fifo) or self.active_slots > 0
                or any(self._queue.values()))

    def run(self, max_steps: int = 10**9) -> List[Completion]:
        done: List[Completion] = []
        for _ in range(max_steps):
            if not self.busy:
                break
            done.extend(self.step())
        return done

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------

    def reset_stats(self) -> None:
        """Zero counters/latencies (e.g. after a warm-up run); jit caches,
        drafter state, and live slots are untouched."""
        self.stats = {"requests": 0, "blocks": 0, "committed": 0,
                      "accepted": 0, "drafted": 0, "updates": 0,
                      "preemptions": 0, "peak_live_slots": 0,
                      "latencies": []}
        self._slot_accepted[:] = 0
        self._slot_drafted[:] = 0

    @property
    def acceptance(self) -> float:
        return self.stats["accepted"] / max(self.stats["drafted"], 1)

    @property
    def slot_acceptance(self) -> np.ndarray:
        """(num_slots,) lifetime acceptance rate per lane."""
        return self._slot_accepted / np.maximum(self._slot_drafted, 1)

    def kv_stats(self) -> dict:
        """Paged-pool observability: utilization / watermark / fragmentation
        plus scheduler-level preemption and concurrency counters."""
        if not self.paged:
            return {"paged": False}
        live_tokens = sum(st.cache_len for st in self._slots if st is not None)
        out = self._pool.utilization(live_tokens)
        out.update(paged=True, preemptions=self.stats["preemptions"],
                   peak_live_slots=self.stats["peak_live_slots"])
        return out

    def latency_percentiles(self) -> dict:
        lats = self.stats["latencies"]
        if not lats:
            return {"p50_s": 0.0, "p95_s": 0.0, "mean_s": 0.0}
        return {"p50_s": float(np.percentile(lats, 50)),
                "p95_s": float(np.percentile(lats, 95)),
                "mean_s": float(np.mean(lats))}
