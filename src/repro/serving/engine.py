"""Continual-learning serving engine: batched requests + DVI online updates.

The paper's deployment story: a single model serves traffic with lossless
speculative speedup, and every verification step doubles as training signal
for the drafter — the engine below is that loop made concrete:

  1. requests are bucketed by prompt length (stateful mixers need packed
     equal-length prefill; buckets pad up to a small set of lengths),
  2. each batch is decoded with ``speculative_generate(collect=True)``,
  3. after each batch, the LoRA drafter takes `updates_per_batch` small
     AdamW steps from the replay buffer (KL->RL schedule),
  4. acceptance statistics are tracked so drift is observable
     (falling acceptance on new traffic recovers as the drafter adapts).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import online as online_mod
from repro.core import spec as spec_mod
from repro.models.model import Model


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (Tp,) int32
    max_new: int = 64


@dataclass
class Completion:
    uid: int
    tokens: np.ndarray
    gen_tokens: np.ndarray
    mat: float
    wall_s: float


@dataclass
class ServingEngine:
    model: Model
    params: dict
    state: online_mod.OnlineTrainerState
    batch_size: int = 8
    max_new: int = 64
    buckets: tuple = (16, 32, 64, 128)
    updates_per_batch: int = 1
    learn: bool = True
    lr: float = 1e-3
    mode: str = "full"
    _queue: Dict[int, List[Request]] = field(default_factory=dict)
    _gen_cache: dict = field(default_factory=dict)
    stats: dict = field(default_factory=lambda: {
        "requests": 0, "blocks": 0, "committed": 0, "accepted": 0,
        "drafted": 0, "updates": 0})

    def __post_init__(self):
        self._update_fn = online_mod.make_update_fn(self.model, self.mode,
                                                    self.lr)
        self._key = jax.random.PRNGKey(1234)

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def submit(self, req: Request) -> None:
        b = self._bucket(len(req.prompt))
        self._queue.setdefault(b, []).append(req)

    def _gen_fn(self, bucket: int):
        if bucket not in self._gen_cache:
            model, max_new = self.model, self.max_new

            @jax.jit
            def gen(params, dvi_params, prompts, buf):
                return spec_mod.speculative_generate(
                    model, params, dvi_params, prompts, max_new,
                    collect=True, buf=buf)
            self._gen_cache[bucket] = gen
        return self._gen_cache[bucket]

    def _pad(self, req: Request, bucket: int) -> np.ndarray:
        p = req.prompt[-bucket:]
        if len(p) < bucket:                      # left-pad by repeating BOS
            p = np.concatenate([np.full(bucket - len(p), p[0], p.dtype), p])
        return p

    def step(self) -> List[Completion]:
        """Serve one batch from the fullest bucket; maybe update the drafter."""
        if not any(self._queue.values()):
            return []
        bucket = max(self._queue, key=lambda b: len(self._queue[b]))
        reqs = self._queue[bucket][:self.batch_size]
        self._queue[bucket] = self._queue[bucket][self.batch_size:]
        while len(reqs) < self.batch_size:       # pad batch with replays
            reqs.append(reqs[-1])
        prompts = jnp.asarray(np.stack([self._pad(r, bucket) for r in reqs]))

        t0 = time.perf_counter()
        res = self._gen_fn(bucket)(self.params, self.state.dvi_params,
                                   prompts, self.state.buf)
        jax.block_until_ready(res.tokens)
        wall = time.perf_counter() - t0
        self.state.buf = res.buffer

        if self.learn:
            for _ in range(self.updates_per_batch):
                self._key, sub = jax.random.split(self._key)
                (self.state.dvi_params, self.state.opt_state,
                 self.state.baseline, _m) = self._update_fn(
                    self.params, self.state.dvi_params, self.state.opt_state,
                    self.state.buf, self.state.baseline, self.state.step, sub)
                self.state.step = self.state.step + 1
                self.stats["updates"] += 1

        mat = float(res.committed) / max(float(res.blocks), 1.0)
        self.stats["requests"] += len(set(r.uid for r in reqs))
        self.stats["blocks"] += int(res.blocks)
        self.stats["committed"] += int(res.committed)
        self.stats["accepted"] += int(res.accepted_drafts)
        self.stats["drafted"] += int(res.drafted)

        outs, seen = [], set()
        toks = np.asarray(res.tokens)
        lens = np.asarray(res.lengths)
        for i, r in enumerate(reqs):
            if r.uid in seen:
                continue
            seen.add(r.uid)
            outs.append(Completion(
                uid=r.uid, tokens=toks[i, :lens[i]],
                gen_tokens=toks[i, bucket:lens[i]],
                mat=mat, wall_s=wall / len(reqs)))
        return outs

    def run(self, max_steps: int = 10**9) -> List[Completion]:
        done: List[Completion] = []
        for _ in range(max_steps):
            out = self.step()
            if not out:
                break
            done.extend(out)
        return done

    @property
    def acceptance(self) -> float:
        return self.stats["accepted"] / max(self.stats["drafted"], 1)
