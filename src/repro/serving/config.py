"""One config surface for the serving engine: EngineConfig + ModelSpec.

``ServingEngine`` grew ~20 constructor knobs across PRs 1-9, and every
entry point (``launch/serve.py``, ``benchmarks/serving_bench.py``, and
now ``launch/api_server.py`` / ``benchmarks/load_gen.py``) re-declared
its own argparse subset of them.  This module hoists both:

* ``EngineConfig`` — a dataclass mirroring the engine's tunable knobs,
  with ``add_args(parser)`` / ``from_args(args)`` so every CLI shares
  ONE flag set (``--num-slots``, ``--kv-pages``, ...), and
  ``engine_kwargs()`` to splat into ``ServingEngine``.  ``to_argv()``
  round-trips a config back to flags (tested), so configs can be
  shipped across process boundaries (e.g. the load generator re-running
  a server's exact engine in-process for stream verification).

* ``ModelSpec`` + ``build_model_bundle`` — the tiny-backbone recipe the
  launchers share (config -> init -> synthetic pretrain -> online
  trainer state), so the HTTP server and the verification path build
  bit-identical models from the same (arch, tiny, seed, pretrain_steps)
  tuple.

Keep knob names here in lockstep with ``ServingEngine``'s fields — the
round-trip test (tests/test_config.py) asserts every EngineConfig field
maps onto a real engine parameter.
"""
from __future__ import annotations

import argparse
from dataclasses import dataclass, field, fields
from typing import Dict, Optional


def parse_tenant_weights(spec: str) -> Optional[Dict[str, float]]:
    """``"a:2,b:1"`` -> ``{"a": 2.0, "b": 1.0}`` (empty/None -> None)."""
    if not spec:
        return None
    out: Dict[str, float] = {}
    for part in spec.split(","):
        name, _, w = part.partition(":")
        if not name:
            raise ValueError(f"bad tenant-weights spec {spec!r}")
        out[name.strip()] = float(w) if w else 1.0
    return out


def format_tenant_weights(weights: Optional[Dict[str, float]]) -> str:
    if not weights:
        return ""
    return ",".join(f"{k}:{v:g}" for k, v in sorted(weights.items()))


@dataclass
class EngineConfig:
    """Every tunable ``ServingEngine`` knob, CLI-addressable.

    Field names match the engine's constructor parameters 1:1; the flag
    for field ``kv_page_size`` is ``--kv-page-size``.
    """
    scheduler: str = "continuous"
    num_slots: int = 8
    batch_size: int = 8
    max_new: int = 64
    bucket: int = 64              # sync-path prompt bucket (buckets=(bucket,))
    update_every: int = 4
    updates_per_batch: int = 1
    sync_every: int = 1
    latency_window: int = 4096
    learn: bool = True
    lr: float = 1e-3
    mode: str = "full"
    eos_id: int = 1
    cache_len: int = 0
    kv_pages: int = 0
    kv_page_size: int = 16
    kv_watermark: int = 0
    prefix_cache: bool = False
    prefill_chunk: int = 0
    adaptive_k: bool = False
    k_min: int = 1
    k_max: int = 0
    max_queue: int = 0
    tenant_weights: Optional[Dict[str, float]] = None
    telemetry: bool = False
    trace_limit: int = 200_000
    profile_dir: Optional[str] = None
    profile_steps: int = 32

    # -- CLI plumbing --------------------------------------------------

    @classmethod
    def add_args(cls, ap: argparse.ArgumentParser,
                 defaults: Optional["EngineConfig"] = None) -> None:
        """Register one ``--flag`` per field (bools become on/off pairs
        only where the default is False; True-default bools get a
        ``--no-...`` switch)."""
        d = defaults or cls()
        g = ap.add_argument_group("engine", "ServingEngine knobs "
                                  "(serving/config.py EngineConfig)")
        g.add_argument("--scheduler", choices=("sync", "continuous"),
                       default=d.scheduler)
        g.add_argument("--num-slots", type=int, default=d.num_slots,
                       help="decode lanes (continuous scheduler)")
        g.add_argument("--batch-size", "--batch", dest="batch_size",
                       type=int, default=d.batch_size,
                       help="requests per batch (sync scheduler)")
        g.add_argument("--max-new", type=int, default=d.max_new)
        g.add_argument("--bucket", type=int, default=d.bucket,
                       help="sync-path prompt-length bucket")
        g.add_argument("--update-every", type=int, default=d.update_every,
                       help="blocks between drafter updates (continuous)")
        g.add_argument("--updates-per-batch", type=int,
                       default=d.updates_per_batch)
        g.add_argument("--sync-every", type=int, default=d.sync_every,
                       help="speculative blocks fused per device sync")
        g.add_argument("--latency-window", type=int, default=d.latency_window)
        g.add_argument("--no-learn", action="store_true",
                       default=not d.learn,
                       help="freeze the drafter (no online updates)")
        g.add_argument("--lr", type=float, default=d.lr)
        g.add_argument("--mode", default=d.mode)
        g.add_argument("--eos-id", type=int, default=d.eos_id)
        g.add_argument("--cache-len", type=int, default=d.cache_len)
        g.add_argument("--kv-pages", type=int, default=d.kv_pages,
                       help=">0: paged KV cache with this many pool pages")
        g.add_argument("--kv-page-size", type=int, default=d.kv_page_size)
        g.add_argument("--kv-watermark", type=int, default=d.kv_watermark)
        g.add_argument("--prefix-cache", action="store_true",
                       default=d.prefix_cache,
                       help="share page-aligned prompt prefixes (paged)")
        g.add_argument("--prefill-chunk", type=int, default=d.prefill_chunk,
                       help=">0: chunked prefill of this many tokens/tick")
        g.add_argument("--adaptive-k", action="store_true",
                       default=d.adaptive_k,
                       help="per-lane acceptance-driven speculation depth")
        g.add_argument("--k-min", type=int, default=d.k_min)
        g.add_argument("--k-max", type=int, default=d.k_max)
        g.add_argument("--max-queue", type=int, default=d.max_queue,
                       help="admission queue bound; submissions past it "
                            "are rejected with QueueFull (0 = unbounded)")
        g.add_argument("--tenant-weights",
                       default=format_tenant_weights(d.tenant_weights),
                       help='weighted-fair shares, e.g. "gold:3,free:1"')
        g.add_argument("--telemetry", action="store_true",
                       default=d.telemetry,
                       help="record the per-request lifecycle trace")
        g.add_argument("--trace-limit", type=int, default=d.trace_limit)
        g.add_argument("--profile-dir", default=d.profile_dir)
        g.add_argument("--profile-steps", type=int, default=d.profile_steps)

    @classmethod
    def from_args(cls, args: argparse.Namespace) -> "EngineConfig":
        kw = {}
        for f in fields(cls):
            if f.name == "learn":
                kw["learn"] = not getattr(args, "no_learn")
            elif f.name == "tenant_weights":
                tw = getattr(args, "tenant_weights")
                kw["tenant_weights"] = (parse_tenant_weights(tw)
                                        if isinstance(tw, str) else tw)
            else:
                kw[f.name] = getattr(args, f.name)
        return cls(**kw)

    def engine_kwargs(self) -> dict:
        """Keyword arguments for ``ServingEngine(model, params, state,
        **kwargs)``."""
        kw = {f.name: getattr(self, f.name) for f in fields(self)
              if f.name != "bucket"}
        kw["buckets"] = (self.bucket,)
        return kw

    def to_argv(self) -> list:
        """Flags that reproduce this config through ``add_args`` +
        ``from_args`` (the round-trip contract)."""
        out = []
        for f in fields(self):
            v = getattr(self, f.name)
            flag = "--" + f.name.replace("_", "-")
            if f.name == "learn":
                if not v:
                    out.append("--no-learn")
            elif f.name == "tenant_weights":
                if v:
                    out += ["--tenant-weights", format_tenant_weights(v)]
            elif isinstance(v, bool):
                if v:
                    out.append(flag)
            elif v is None:
                continue
            else:
                out += [flag, str(v)]
        return out


def build_engine(config: EngineConfig, model, params, state, **overrides):
    """``ServingEngine`` from one config object (+ keyword overrides)."""
    from repro.serving.engine import ServingEngine
    kw = config.engine_kwargs()
    kw.update(overrides)
    return ServingEngine(model, params, state, **kw)


# ---------------------------------------------------------------------------
# shared model-build recipe
# ---------------------------------------------------------------------------

@dataclass
class ModelSpec:
    """The (arch, tiny, seed, pretrain_steps) tuple that pins a serving
    model bit-exactly — two processes building the same spec (same
    PYTHONHASHSEED for the synthetic task stream) decode identical
    streams, which is what load_gen's --verify-direct asserts."""
    arch: str = "vicuna-7b"
    tiny: bool = True
    seed: int = 0
    pretrain_steps: int = 200

    @classmethod
    def add_args(cls, ap: argparse.ArgumentParser,
                 defaults: Optional["ModelSpec"] = None) -> None:
        d = defaults or cls()
        g = ap.add_argument_group("model", "backbone spec (ModelSpec)")
        g.add_argument("--arch", default=d.arch)
        g.add_argument("--tiny", action="store_true", default=d.tiny)
        g.add_argument("--full-size", action="store_true",
                       help="disable --tiny (full-size backbone)")
        g.add_argument("--seed", type=int, default=d.seed)
        g.add_argument("--pretrain-steps", type=int, default=d.pretrain_steps)

    @classmethod
    def from_args(cls, args: argparse.Namespace) -> "ModelSpec":
        return cls(arch=args.arch,
                   tiny=args.tiny and not getattr(args, "full_size", False),
                   seed=args.seed, pretrain_steps=args.pretrain_steps)


def build_model_bundle(spec: ModelSpec):
    """(cfg, model, params, tasks, state): the launcher recipe — config ->
    init -> synthetic pretrain -> fresh online-trainer state.  Deferred
    imports keep ``serving.config`` importable without pulling jax at
    module load (argparse-only callers)."""
    import jax

    from repro.configs import get_config
    from repro.core import online as online_mod
    from repro.data import SyntheticTasks, TASK_CATEGORIES
    from repro.models.model import build_model
    from repro.training import pretrain

    cfg = get_config(spec.arch, tiny=spec.tiny).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(spec.seed))
    tasks = SyntheticTasks(cfg.vocab_size, seed=spec.seed)
    params, _ = pretrain(
        model, params,
        tasks.stream(TASK_CATEGORIES, spec.pretrain_steps, 8, 32,
                     seed=spec.seed + 1), lr=2e-3)
    state = online_mod.init_trainer(model, jax.random.PRNGKey(spec.seed + 7))
    return cfg, model, params, tasks, state
