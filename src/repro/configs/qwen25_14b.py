"""Qwen2.5-14B [hf:Qwen/Qwen2.5-0.5B family] — dense, GQA, QKV bias."""
from repro.configs.base import DVIConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    arch_type="dense",
    num_layers=48,
    d_model=5_120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=13_824,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    dvi=DVIConfig(split_layer=2),
    citation="hf:Qwen/Qwen2.5-0.5B",
)

TINY = CONFIG.replace(
    name="qwen2.5-14b-tiny",
    num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512,
    dvi=DVIConfig(split_layer=1, lora_rank=8, buffer_slots=512, batch_size=64),
)
