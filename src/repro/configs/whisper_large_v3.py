"""Whisper large-v3 [arXiv:2212.04356] — enc-dec; conv/mel frontend stubbed
to precomputed frame embeddings (assignment carve-out)."""
from repro.configs.base import DVIConfig, EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    arch_type="audio",
    num_layers=32,                 # decoder layers
    d_model=1_280,
    num_heads=20,
    num_kv_heads=20,               # MHA
    head_dim=64,
    d_ff=5_120,
    vocab_size=51_866,
    act="gelu",
    glu=False,                     # plain GELU MLP
    tie_embeddings=True,
    encoder=EncoderConfig(num_layers=32, num_frames=1_500),
    dvi=DVIConfig(split_layer=2),
    citation="arXiv:2212.04356",
)

TINY = CONFIG.replace(
    name="whisper-large-v3-tiny",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
    d_ff=512, vocab_size=512,
    encoder=EncoderConfig(num_layers=2, num_frames=24),
    dvi=DVIConfig(split_layer=1, lora_rank=8, buffer_slots=512, batch_size=64),
)
