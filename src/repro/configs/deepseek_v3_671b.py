"""DeepSeek-V3 671B [arXiv:2412.19437] — MLA, 1 shared + 256 routed top-8 MoE,
first 3 layers dense, optional MTP auxiliary head."""
from repro.configs.base import DVIConfig, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    num_layers=61,
    d_model=7_168,
    num_heads=128,
    num_kv_heads=128,              # MLA: heads share a compressed latent KV
    head_dim=128,
    d_ff=2_048,                    # routed expert intermediate size
    vocab_size=129_280,
    rope_theta=10_000.0,
    mla=MLAConfig(q_lora_rank=1_536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2_048,
                  num_shared_experts=1, d_ff_shared=2_048,
                  first_dense_layers=3, d_ff_dense=18_432),
    mtp_depth=1,
    dvi=DVIConfig(split_layer=2),
    citation="arXiv:2412.19437",
)

TINY = CONFIG.replace(
    name="deepseek-v3-671b-tiny",
    num_layers=3, d_model=256, num_heads=4, num_kv_heads=4, head_dim=32,
    d_ff=128, vocab_size=512,
    mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                  qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32),
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                  num_shared_experts=1, d_ff_shared=128,
                  first_dense_layers=1, d_ff_dense=256, capacity_factor=8.0),
    mtp_depth=0,
    dvi=DVIConfig(split_layer=1, lora_rank=8, buffer_slots=512, batch_size=64),
)
