"""RecurrentGemma-9B [arXiv:2402.19427] — hybrid RG-LRU + local attention, 1:2."""
from repro.configs.base import DVIConfig, ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    num_layers=38,                      # 12 full (rglru, rglru, local) periods + 2-layer tail
    d_model=4_096,
    num_heads=16,
    num_kv_heads=1,                     # MQA
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    act="gelu",
    glu=True,                           # GeGLU
    rglru=RGLRUConfig(lru_width=4_096, block_pattern=("rglru", "rglru", "local"),
                      local_window=2_048),
    dvi=DVIConfig(split_layer=2),
    citation="arXiv:2402.19427",
)

TINY = CONFIG.replace(
    name="recurrentgemma-9b-tiny",
    num_layers=3, d_model=256, num_heads=4, num_kv_heads=1, head_dim=64,
    d_ff=512, vocab_size=512,
    rglru=RGLRUConfig(lru_width=256, block_pattern=("rglru", "rglru", "local"),
                      local_window=64),
    dvi=DVIConfig(split_layer=1, lora_rank=8, buffer_slots=512, batch_size=64),
)
