"""Llama-3.1 405B [arXiv:2407.21783] — dense, GQA, 128k vocab."""
from repro.configs.base import DVIConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    arch_type="dense",
    num_layers=126,
    d_model=16_384,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    d_ff=53_248,
    vocab_size=128_256,
    rope_theta=500_000.0,
    act="silu",
    glu=True,
    dvi=DVIConfig(split_layer=2),
    citation="arXiv:2407.21783",
)

# Reduced same-family variant for CPU smoke tests.
TINY = CONFIG.replace(
    name="llama3-405b-tiny",
    num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512, dvi=DVIConfig(split_layer=1, lora_rank=8,
                                            buffer_slots=512, batch_size=64),
)
