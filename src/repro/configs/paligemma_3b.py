"""PaliGemma-3B [arXiv:2407.07726] — SigLIP vision stub + Gemma-2B decoder."""
from repro.configs.base import DVIConfig, ModelConfig, VisionStubConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    arch_type="vlm",
    num_layers=18,
    d_model=2_048,
    num_heads=8,
    num_kv_heads=1,               # MQA
    head_dim=256,
    d_ff=16_384,
    vocab_size=257_216,
    act="gelu",
    glu=True,                     # GeGLU
    tie_embeddings=True,
    vision=VisionStubConfig(num_patches=256, d_embed=1_152),  # SigLIP-so400m 224px/14
    dvi=DVIConfig(split_layer=2),
    citation="arXiv:2407.07726",
)

TINY = CONFIG.replace(
    name="paligemma-3b-tiny",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=1, head_dim=64,
    d_ff=512, vocab_size=512,
    vision=VisionStubConfig(num_patches=16, d_embed=96),
    dvi=DVIConfig(split_layer=1, lora_rank=8, buffer_slots=512, batch_size=64),
)
