"""Qwen3-1.7B [hf:Qwen/Qwen3-8B family] — dense, GQA, per-head qk RMSNorm."""
from repro.configs.base import DVIConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    arch_type="dense",
    num_layers=28,
    d_model=2_048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6_144,
    vocab_size=151_936,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    dvi=DVIConfig(split_layer=2),
    citation="hf:Qwen/Qwen3-8B",
)

TINY = CONFIG.replace(
    name="qwen3-1.7b-tiny",
    num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512,
    dvi=DVIConfig(split_layer=1, lora_rank=8, buffer_slots=512, batch_size=64),
)
