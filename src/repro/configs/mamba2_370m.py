"""Mamba2-370M [arXiv:2405.21060] — attention-free SSD (state-space duality)."""
from repro.configs.base import DVIConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    num_layers=48,
    d_model=1_024,
    num_heads=32,                  # d_inner / head_dim = 2048 / 64
    num_kv_heads=32,
    d_ff=0,                        # attention-free, no MLP (Mamba-2 block only)
    vocab_size=50_280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=128),
    dvi=DVIConfig(split_layer=2),
    citation="arXiv:2405.21060",
)

TINY = CONFIG.replace(
    name="mamba2-370m-tiny",
    num_layers=2, d_model=256, num_heads=8, num_kv_heads=8, vocab_size=512,
    ssm=SSMConfig(d_state=32, d_conv=4, expand=2, head_dim=64, chunk_size=32),
    dvi=DVIConfig(split_layer=1, lora_rank=8, buffer_slots=512, batch_size=64),
)
