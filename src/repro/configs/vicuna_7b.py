"""Vicuna-7B [arXiv:2306.05685] — the paper's own Spec-Bench backbone
(LLaMA-1 7B geometry).  Not part of the assigned pool; included because the
paper's experiments use it (split k=2, k_spec=4)."""
from repro.configs.base import DVIConfig, ModelConfig

CONFIG = ModelConfig(
    name="vicuna-7b",
    arch_type="dense",
    num_layers=32,
    d_model=4_096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11_008,
    vocab_size=32_000,
    rope_theta=10_000.0,
    dvi=DVIConfig(split_layer=2, k_spec=4),
    citation="arXiv:2306.05685 (Spec-Bench backbone)",
)

TINY = CONFIG.replace(
    name="vicuna-7b-tiny",
    num_layers=4, d_model=256, num_heads=8, num_kv_heads=8, head_dim=32,
    d_ff=512, vocab_size=512,
    dvi=DVIConfig(split_layer=2, k_spec=4, lora_rank=8,
                  buffer_slots=512, batch_size=64),
)
