"""Architecture registry: the 10 assigned configs + the paper's Vicuna-7B."""
from __future__ import annotations

from repro.configs.base import (DVIConfig, EncoderConfig, InputShape,
                                INPUT_SHAPES, MLAConfig, ModelConfig,
                                MoEConfig, RGLRUConfig, SSMConfig,
                                VisionStubConfig)
from repro.configs import (deepseek_v3_671b, llama3_405b,
                           llama4_scout_17b_a16e, mamba2_370m, paligemma_3b,
                           qwen25_14b, qwen3_0_6b, qwen3_1_7b,
                           recurrentgemma_9b, vicuna_7b, whisper_large_v3)

_MODULES = {
    "llama3-405b": llama3_405b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "qwen2.5-14b": qwen25_14b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "paligemma-3b": paligemma_3b,
    "whisper-large-v3": whisper_large_v3,
    "qwen3-0.6b": qwen3_0_6b,
    "mamba2-370m": mamba2_370m,
    "deepseek-v3-671b": deepseek_v3_671b,
    "qwen3-1.7b": qwen3_1_7b,
    "vicuna-7b": vicuna_7b,
}

ASSIGNED_ARCHS = [n for n in _MODULES if n != "vicuna-7b"]
ALL_ARCHS = list(_MODULES)


def get_config(name: str, *, tiny: bool = False) -> ModelConfig:
    base = name[:-5] if name.endswith("-tiny") else name
    if base not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ALL_ARCHS}")
    cfg = _MODULES[base].TINY if (tiny or name.endswith("-tiny")) else _MODULES[base].CONFIG
    cfg.validate()
    return cfg


__all__ = [
    "ALL_ARCHS", "ASSIGNED_ARCHS", "DVIConfig", "EncoderConfig", "INPUT_SHAPES",
    "InputShape", "MLAConfig", "ModelConfig", "MoEConfig", "RGLRUConfig",
    "SSMConfig", "VisionStubConfig", "get_config",
]
