"""Llama-4 Scout 17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E] — MoE top-1 +
shared expert, chunked local attention with periodic global (iRoPE-style)."""
from repro.configs.base import DVIConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    num_layers=48,
    d_model=5_120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8_192,
    vocab_size=202_048,
    rope_theta=500_000.0,
    sliding_window=8_192,          # chunked local attention
    global_attn_every=4,           # every 4th layer is global (NoPE/iRoPE style)
    moe=MoEConfig(num_experts=16, top_k=1, d_ff_expert=8_192,
                  num_shared_experts=1, d_ff_shared=8_192),
    dvi=DVIConfig(split_layer=2),
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
)

TINY = CONFIG.replace(
    name="llama4-scout-17b-a16e-tiny",
    num_layers=4, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512, sliding_window=64, global_attn_every=4,
    moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=512,
                  num_shared_experts=1, d_ff_shared=512, capacity_factor=8.0),
    dvi=DVIConfig(split_layer=1, lora_rank=8, buffer_slots=512, batch_size=64),
)
