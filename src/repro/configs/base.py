"""Configuration system for the DVI reproduction framework.

Every assigned architecture gets a ``ModelConfig`` (exact published sizes)
plus a ``tiny()`` reduced variant used by CPU smoke tests.  The DVI
technique itself is configured by ``DVIConfig`` and is attachable to any
architecture (self-speculation splits the decoder stack at ``split_layer``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    router_aux_weight: float = 0.01
    capacity_factor: float = 1.25   # full-sequence dispatch; decode is dropless
    # layers 0..first_dense_layers-1 use a dense FFN instead of MoE
    first_dense_layers: int = 0
    d_ff_dense: int = 0


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention [arXiv:2412.19437]."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block [arXiv:2405.21060]."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 128
    ngroups: int = 1


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU recurrent block [arXiv:2402.19427]."""
    lru_width: int = 0          # 0 => d_model
    d_conv: int = 4
    block_pattern: Tuple[str, ...] = ("rglru", "rglru", "local")  # 1:2 attn:recurrent
    local_window: int = 2048


@dataclass(frozen=True)
class EncoderConfig:
    """Audio/vision encoder backbone (frontend stubbed to embeddings)."""
    num_layers: int
    num_frames: int            # precomputed frame/patch positions fed by input_specs()
    d_model: int = 0           # 0 => same as decoder d_model
    num_heads: int = 0


@dataclass(frozen=True)
class VisionStubConfig:
    """VLM prefix: precomputed patch embeddings prepended to the text tokens."""
    num_patches: int
    d_embed: int               # dim of incoming patch embeddings (pre-projector)


@dataclass(frozen=True)
class DVIConfig:
    """Draft, Verify, & Improve (the paper's technique)."""
    split_layer: int = 2          # draft path = layers [0, split_layer)
    k_spec: int = 4               # proposal depth
    lora_rank: int = 64
    lora_alpha: float = 128.0     # gamma_s = alpha / rank
    # loss weights (L_fast)
    lambda_kl0: float = 1.0       # lambda_0: KL weight during warmup
    lambda_kl_min: float = 0.1
    lambda_pg_max: float = 1.0
    w_ce: float = 0.5
    w_ent: float = 0.001
    kd_temperature: float = 2.0   # tau for p_phi^(tau)
    # on-policy correction (L_policy)
    w_rl: float = 0.5
    beta0: float = 0.3            # beta(t) init, decays to beta_min
    beta_min: float = 0.03
    beta_decay_steps: int = 1000
    baseline_ema: float = 0.95    # EMA of recent rewards (variance-reduction baseline b)
    # schedule
    warmup_steps: int = 200       # T_warmup: KL-only
    ramp_steps: int = 400         # T_ramp: linear KL->RL
    # buffer
    buffer_slots: int = 4096
    batch_size: int = 256         # tuples per update minibatch


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // num_heads
    # attention flavor
    qk_norm: bool = False          # per-head RMSNorm on q,k (Qwen3)
    qkv_bias: bool = False         # (Qwen2.5)
    rope_theta: float = 10000.0
    sliding_window: int = 0        # 0 => full attention
    global_attn_every: int = 0     # >0: every Nth layer is full-attn (llama4 iRoPE-style)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "silu"              # silu (SwiGLU) | gelu (GeGLU / plain)
    glu: bool = True
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionStubConfig] = None
    dvi: DVIConfig = field(default_factory=DVIConfig)
    # MTP (DeepSeek-V3 multi-token prediction) auxiliary head
    mtp_depth: int = 0
    # int8 KV cache (per-slot per-kv-head symmetric scales); halves decode
    # cache bytes — beyond-paper serving optimization, EXPERIMENTS.md §Perf H5
    kv_quant: bool = False
    dtype: str = "bfloat16"
    citation: str = ""

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def layer_pattern(self) -> Tuple[str, ...]:
        """Repeating per-layer block pattern, length divides num_layers."""
        if self.arch_type == "ssm":
            return ("ssm",)
        if self.rglru is not None:
            return self.rglru.block_pattern
        if self.global_attn_every and self.sliding_window:
            pat = ["local"] * self.global_attn_every
            pat[-1] = "attn"
            return tuple(pat)
        if self.sliding_window:
            return ("local",)
        return ("attn",)

    def validate(self) -> None:
        assert self.arch_type in ("dense", "moe", "ssm", "hybrid", "vlm", "audio")
        if self.arch_type != "ssm":
            assert self.num_heads % max(self.num_kv_heads, 1) == 0, "GQA group size"
        # NOTE: layer_pattern period need not divide num_layers; the
        # transformer stacks full periods via lax.scan and unrolls the tail
        # (e.g. RecurrentGemma-9B: 38 = 12*(r,r,l) + (r,r)).
        assert 0 < self.dvi.split_layer < self.num_layers
        if self.moe is not None:
            assert self.moe.top_k <= self.moe.num_experts
            assert self.moe.first_dense_layers < self.num_layers

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        n = V * d                                   # embed
        if not self.tie_embeddings:
            n += V * d                              # lm head
        per_layer_attn = 0
        if self.mla is not None:
            m = self.mla
            per_layer_attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                + self.num_heads * m.v_head_dim * d)
        elif self.arch_type == "ssm":
            s = self.ssm
            d_in = s.expand * d
            per_layer_attn = d * (2 * d_in + 2 * s.ngroups * s.d_state + d_in // s.head_dim) \
                + d_in * d
        else:
            per_layer_attn = d * (self.num_heads + 2 * self.num_kv_heads) * hd \
                + self.num_heads * hd * d
        glu_mult = 3 if self.glu else 2
        if self.moe is not None:
            mo = self.moe
            moe_layers = L - mo.first_dense_layers
            ffn = mo.first_dense_layers * glu_mult * d * (mo.d_ff_dense or self.d_ff)
            ffn += moe_layers * (
                mo.num_experts * glu_mult * d * mo.d_ff_expert
                + mo.num_shared_experts * glu_mult * d * (mo.d_ff_shared or mo.d_ff_expert)
                + d * mo.num_experts)
        elif self.arch_type == "ssm":
            ffn = 0
        else:
            ffn = L * glu_mult * d * self.d_ff
        n += L * per_layer_attn + ffn + 2 * L * d
        if self.encoder is not None:
            e = self.encoder
            ed = e.d_model or d
            # encoder self-attn + ffn + decoder cross-attn
            n += e.num_layers * (4 * ed * ed + glu_mult * ed * self.d_ff)
            n += L * 4 * d * ed
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        d, L = self.d_model, self.num_layers
        glu_mult = 3 if self.glu else 2
        moe_layers = L - mo.first_dense_layers
        dense_total = self.param_count() - moe_layers * (
            mo.num_experts * glu_mult * d * mo.d_ff_expert)
        return dense_total + moe_layers * mo.top_k * glu_mult * d * mo.d_ff_expert

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}
