"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked  # canonical SSD oracle (re-export)


def ref_verify_argmax(h: jax.Array, w: jax.Array):
    """h (T, d), w (d, V) -> (argmax (T,) int32, maxval (T,) f32).

    The verifier's greedy emission y* = argmax_v (h @ w) — the paper's
    verification rule — computed naively (materializes the full logits)."""
    logits = jnp.dot(h, w, preferred_element_type=jnp.float32)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), logits.max(axis=-1)


def ref_lora_logits(h: jax.Array, w: jax.Array, a: jax.Array, b: jax.Array,
                    gamma: float):
    """Draft head logits (W_S + gamma A B) h, materialized.  f32 out."""
    base = jnp.dot(h, w, preferred_element_type=jnp.float32)
    lora = jnp.dot(jnp.dot(h, a, preferred_element_type=jnp.float32), b,
                   preferred_element_type=jnp.float32)
    return base + gamma * lora


def ref_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array, scale: float | None = None):
    """q (B, H, hd); k/v (B, S, KV, hd); lengths (B,): attend slots < len.
    Returns (B, H, hd)."""
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / jnp.sqrt(jnp.float32(hd))
    qg = q.reshape(B, KV, G, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k).astype(jnp.float32) * scale
    mask = jnp.arange(S)[None, :] < lengths[:, None]          # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v)
    return out.reshape(B, H, hd)


def ref_paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, lengths: jax.Array,
                               block_tables: jax.Array,
                               scale: float | None = None,
                               page_counts: jax.Array | None = None):
    """Paged oracle: q (B, H, hd); k_pages/v_pages (P, ps, KV, hd) pooled
    pages (page 0 = null); lengths (B,); block_tables (B, MPS) int32
    (-1 = unmapped).  Materializes each lane's logical view through the
    block table, then attends slots j < length on mapped pages.
    `page_counts` (B,) mirrors the Pallas kernel's per-lane early-out: only
    the first page_counts[b] logical pages of lane b participate (identical
    output whenever the counts cover `lengths`, which is the kernel's
    default)."""
    from repro.serving.kv_pool import logical_to_physical
    B, H, hd = q.shape
    P, ps, KV = k_pages.shape[:3]
    MPS = block_tables.shape[1]
    L = MPS * ps
    j = jnp.arange(L)
    rpage, rphys = logical_to_physical(
        block_tables, jnp.broadcast_to(j[None, :], (B, L)), ps)   # (B, L)
    kf = k_pages.reshape((P * ps, KV, hd))[rphys]             # (B, L, KV, hd)
    vf = v_pages.reshape((P * ps, KV, hd))[rphys]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(jnp.float32(hd))
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, kf).astype(jnp.float32) * scale
    mask = (rpage >= 0) & (j[None, :] < lengths[:, None])     # (B, L)
    if page_counts is not None:
        pc = jnp.clip(page_counts.astype(jnp.int32), 1, MPS)
        mask &= (j[None, :] // ps) < pc[:, None]
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(vf.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", p, vf)
    return out.reshape(B, H, hd)


def ref_ssd_scan(xh, Bc, Cc, dt, A, chunk: int, h0=None):
    """Alias of the model-level chunked SSD (see repro.models.ssm)."""
    return ssd_chunked(xh, Bc, Cc, dt, A, chunk, h0=h0)
