"""Mamba-2 SSD chunked scan kernel (state-space duality, arXiv:2405.21060).

TPU formulation: grid (B, T/Q) with the chunk axis sequential; the running
SSD state (H, hd, ds) lives in VMEM scratch across chunk steps.  Each chunk
does the intra-chunk quadratic term (two MXU einsums through a (Q, Q, H)
decay-masked attention-like tensor), the inter-chunk contribution from the
carried state, and the state update — i.e. the same decomposition as the
pure-jnp oracle ``repro.kernels.ref.ref_ssd_scan``, with chunk length Q=128
matched to MXU tiling.

G (B/C groups) == 1 here (Mamba-2 default); dt is pre-softplus-ed by the
wrapper caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, y_ref, hout_ref, hstate_ref,
            *, Q: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        hstate_ref[...] = jnp.zeros_like(hstate_ref)

    x = x_ref[0].astype(jnp.float32)               # (Q, H, hd)
    Bc = b_ref[0].astype(jnp.float32)              # (Q, ds)   (G == 1)
    Cc = c_ref[0].astype(jnp.float32)              # (Q, ds)
    dt = dt_ref[0].astype(jnp.float32)             # (Q, H)
    A = a_ref[...]                                 # (H,)

    dA = dt * A[None, :]                           # (Q, H)
    cum = jnp.cumsum(dA, axis=0)                   # inclusive
    seg = cum[:, None, :] - cum[None, :, :]        # (Q, Q, H)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tri = (jj <= ii)[:, :, None]
    decay = jnp.where(tri, jnp.exp(seg), 0.0)      # (Q, Q, H)
    cb = jnp.dot(Cc, Bc.T, preferred_element_type=jnp.float32)  # (Q, Q)
    att = cb[:, :, None] * decay * dt[None, :, :]  # (Q, Q, H)
    y_intra = jnp.einsum("ijh,jhd->ihd", att, x)

    # inter-chunk from carried state
    h_in = hstate_ref[...]                         # (H, hd, ds)
    y_inter = jnp.einsum("is,hds,ih->ihd", Cc, h_in, jnp.exp(cum))
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h_out = exp(sum dA) h_in + sum_j exp(cum_last-cum_j) dt_j B_j x_j
    dec_out = jnp.exp(cum[-1:, :] - cum) * dt      # (Q, H)
    chunk_state = jnp.einsum("jh,js,jhd->hds", dec_out, Bc, x)
    hstate_ref[...] = h_in * jnp.exp(cum[-1])[:, None, None] + chunk_state

    @pl.when(ci == nc - 1)
    def _finish():
        hout_ref[0] = hstate_ref[...]


def ssd_scan(xh: jax.Array, Bc: jax.Array, Cc: jax.Array, dt: jax.Array,
             A: jax.Array, chunk: int = 128, *, interpret: bool = False):
    """xh (B,T,H,hd); Bc/Cc (B,T,1,ds); dt (B,T,H) post-softplus; A (H,) < 0.
    T % chunk == 0.  Returns (y (B,T,H,hd), final_state (B,H,hd,ds))."""
    B, T, H, hd = xh.shape
    ds = Bc.shape[-1]
    assert Bc.shape[2] == 1, "kernel supports G=1 (Mamba-2 default)"
    assert T % chunk == 0
    Q = chunk
    nc = T // Q
    Bc2 = Bc[:, :, 0, :]
    Cc2 = Cc[:, :, 0, :]

    y, h_final = pl.pallas_call(
        functools.partial(_kernel, Q=Q),
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, Q, H, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, Q, ds), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, ds), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, H, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, H, hd, ds), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, hd), xh.dtype),
            jax.ShapeDtypeStruct((B, H, hd, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, hd, ds), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xh, Bc2, Cc2, dt, A)
    return y, h_final
