"""Shared Pallas-TPU version-compat shims for all kernels in this package.

jax 0.5+ renamed ``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``;
every kernel imports the resolved alias from here instead of re-deriving it.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = (getattr(pltpu, "CompilerParams", None)
                  or pltpu.TPUCompilerParams)

__all__ = ["CompilerParams"]
