"""Paged flash-decode GQA attention: K/V tiles gathered via a block table.

Same online-softmax flash-decode loop as ``decode_attention``, but the KV
cache is the paged pool layout (``repro.serving.kv_pool``): physical pages
``(P, page_size, KV, hd)`` shared by every lane, addressed through a
per-lane block table ``(B, max_pages)`` (int32, -1 = unmapped, physical
page 0 = null page).

The block table, per-lane lengths, AND per-lane active page counts ride in
as **scalar-prefetch** operands (``pltpu.PrefetchScalarGridSpec``), so the
BlockSpec index map resolves the *physical* page to DMA before the kernel
body runs — the grid walks logical pages, the memory system fetches
``tbl[b, p]``.  Unmapped entries clamp onto the null page; their scores are
masked to -inf (the same rule the jnp model path applies), so null-page
garbage never reaches the accumulator.  A page holding slots past the
lane's length (the eager speculative tail) is masked per-slot by
``j < length``.

Grid: (B, KV, max_pages) — batch and kv-head parallel, logical pages
innermost sequential.  **Per-lane early-out**: pages at or beyond the
lane's active page count contribute nothing, so the index map clamps them
onto the lane's LAST active page (a repeated block index means Mosaic
skips the DMA — the tile is already resident) and the kernel body skips
the flash update entirely (``pl.when(p < page_count)``); the output is
written the moment the lane's last active page retires instead of at the
end of the sweep.  A lane holding 2 of 64 pages therefore pays 2 tiles of
DMA + compute, not 64 — the remaining grid steps are empty husks.
``page_counts`` defaults to ``ceil(lengths / page_size)`` and may be
passed explicitly (e.g. to force the full masked sweep for benchmarking).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

NEG = -1e30


def _kernel(tbl_ref, len_ref, pc_ref, q_ref, k_ref, v_ref, o_ref, m_ref,
            l_ref, acc_ref, *, ps: int, scale: float):
    b = pl.program_id(0)
    p = pl.program_id(2)
    pc = pc_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(p < pc)
    def _update():
        q = q_ref[0, 0]                            # (G, hd)
        k = k_ref[0, :, 0, :]                      # (ps, hd)
        v = v_ref[0, :, 0, :]
        length = len_ref[b]
        mapped = tbl_ref[b, p] >= 0

        scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        j = p * ps + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        scores = jnp.where(mapped & (j < length), scores, NEG)

        m_prev = m_ref[...]                        # (G,)
        m_cur = jnp.maximum(m_prev, scores.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        pexp = jnp.exp(scores - m_cur[:, None])    # (G, ps)
        l_ref[...] = l_ref[...] * alpha + pexp.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jnp.dot(pexp, v.astype(jnp.float32),
                                  preferred_element_type=jnp.float32))
        m_ref[...] = m_cur

    @pl.when(p == pc - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, lengths: jax.Array,
                           block_tables: jax.Array, *,
                           page_counts: jax.Array | None = None,
                           interpret: bool = False):
    """q (B, H, hd); k_pages/v_pages (P, ps, KV, hd); lengths (B,);
    block_tables (B, MPS) int32; page_counts (B,) int32 active pages per
    lane (default ceil(lengths / ps)) -> out (B, H, hd)."""
    B, H, hd = q.shape
    P, ps, KV = k_pages.shape[:3]
    MPS = block_tables.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    scale = 1.0 / math.sqrt(hd)
    if page_counts is None:
        page_counts = (lengths.astype(jnp.int32) + ps - 1) // ps
    page_counts = jnp.clip(page_counts.astype(jnp.int32), 1, MPS)

    def kv_map(b, h, p, tbl, lens, pc):
        # beyond the lane's active pages: revisit the last active page so
        # the pipeline issues no new DMA for the skipped grid steps
        pe = jnp.minimum(p, pc[b] - 1)
        return (jnp.maximum(tbl[b, pe], 0), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, KV, MPS),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, h, p, tbl, lens, pc: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, p, tbl, lens, pc: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, ps=ps, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, lengths, page_counts, qg, k_pages, v_pages)
    return out.reshape(B, H, hd)
