"""Fused verifier head: vocab-tiled matmul + running argmax.

The verifier's greedy rule y* = argmax_v softmax(W h_L) never needs the
softmax or the full logits row — only the argmax.  On TPU we tile the vocab
dimension, compute each (T_blk x V_blk) logits block on the MXU in VMEM,
and fold it into a running (max, argmax) pair held in the (revisited)
output blocks.  The (T, V) logits tensor never touches HBM: for a 128k
vocab this deletes a T x 128256 x 4B round-trip per verification step and
turns the verify head from memory-bound to compute-bound (see DESIGN.md §3).

Grid: (T/bt, V/bv), vocab innermost ('arbitrary' — sequential accumulate).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

NEG = -1e30


def _kernel(h_ref, w_ref, arg_ref, max_ref, *, bv: int, v_real: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        max_ref[...] = jnp.full_like(max_ref, NEG)
        arg_ref[...] = jnp.zeros_like(arg_ref)

    logits = jnp.dot(h_ref[...], w_ref[...],
                     preferred_element_type=jnp.float32)      # (bt, bv)
    cols = j * bv + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(cols < v_real, logits, NEG)            # mask vocab pad
    lmax = jnp.max(logits, axis=-1)
    larg = jnp.argmax(logits, axis=-1).astype(jnp.int32) + j * bv
    run_max = max_ref[...]
    upd = lmax > run_max
    arg_ref[...] = jnp.where(upd, larg, arg_ref[...])
    max_ref[...] = jnp.where(upd, lmax, run_max)


def verify_argmax(h: jax.Array, w: jax.Array, *, block_t: int = 128,
                  block_v: int = 2048, interpret: bool = False):
    """h (T, d), w (d, V) -> (argmax (T,) int32, maxval (T,) f32)."""
    T, d = h.shape
    V = w.shape[1]
    bt = min(block_t, max(8, T))
    bv = min(block_v, V)
    Tp = -(-T // bt) * bt
    Vp = -(-V // bv) * bv
    if Tp != T:
        h = jnp.pad(h, ((0, Tp - T), (0, 0)))
    if Vp != V:
        w = jnp.pad(w, ((0, 0), (0, Vp - V)))

    grid = (Tp // bt, Vp // bv)
    arg, mx = pl.pallas_call(
        functools.partial(_kernel, bv=bv, v_real=V),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bv), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda i, j: (i,)),
            pl.BlockSpec((bt,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Tp,), jnp.int32),
            jax.ShapeDtypeStruct((Tp,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(h, w)
    return arg[:T], mx[:T]
