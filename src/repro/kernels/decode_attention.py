"""Flash-decode GQA attention over a contiguous KV cache (single query).

Dispatch through ``repro.kernels.ops.decode_attention`` (the single entry
point choosing ref vs Pallas vs paged); this module only holds the
contiguous Pallas implementation.

TPU adaptation of flash-decoding: the KV sequence is blocked; each grid
step stages one (bs, hd) K/V tile HBM->VMEM, updates an online-softmax
accumulator (m, l, acc) held in VMEM scratch for the whole q-head *group*
sharing that KV head (GQA: G = H / KV query heads per KV head), and the
normalized output is written once on the last block.  Length masking uses
the per-sequence cache length (slots >= length are dead speculative writes).

Grid: (B, KV, S/bs) — batch and kv-head parallel, seq innermost sequential.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, bs: int, scale: float):
    s = pl.program_id(2)
    nsb = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                # (G, hd)
    k = k_ref[0, :, 0, :]                          # (bs, hd)
    v = v_ref[0, :, 0, :]
    length = len_ref[0]

    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, bs)
    slot = s * bs + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(slot < length, scores, NEG)

    m_prev = m_ref[...]                            # (G,)
    m_cur = jnp.maximum(m_prev, scores.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(scores - m_cur[:, None])           # (G, bs)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jnp.dot(p, v.astype(jnp.float32),
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_cur

    @pl.when(s == nsb - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            lengths: jax.Array, *, block_s: int = 512,
                            interpret: bool = False):
    """q (B, H, hd); k/v (B, S, KV, hd); lengths (B,) -> out (B, H, hd)."""
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    bs = min(block_s, S)
    Sp = -(-S // bs) * bs
    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    qg = q.reshape(B, KV, G, hd)
    scale = 1.0 / math.sqrt(hd)

    out = pl.pallas_call(
        functools.partial(_kernel, bs=bs, scale=scale),
        grid=(B, KV, Sp // bs),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (b,)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, qg, k, v)
    return out.reshape(B, H, hd)
