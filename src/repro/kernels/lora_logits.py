"""Fused LoRA draft-head logits: (W_S + gamma A B) h in one vocab-tiled pass.

The rank-r bottleneck u = h @ A is computed once per row-block (at the first
vocab tile) and parked in VMEM scratch; every vocab tile then fuses
``h @ W_blk + gamma * u @ B_blk`` on the MXU.  Compared to the unfused
``h@W + (h@A)@B`` this reads/writes the (T, V) logits exactly once and never
materializes the (T, r) intermediate in HBM.

Grid: (T/bt, V/bv), vocab innermost ('arbitrary' — scratch reuse).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _kernel(h_ref, w_ref, a_ref, b_ref, out_ref, u_ref, *, gamma: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _proj():
        u_ref[...] = jnp.dot(h_ref[...], a_ref[...],
                             preferred_element_type=jnp.float32)

    base = jnp.dot(h_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    lora = jnp.dot(u_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    out_ref[...] = base + gamma * lora


def lora_logits(h: jax.Array, w: jax.Array, a: jax.Array, b: jax.Array,
                gamma: float, *, block_t: int = 128, block_v: int = 2048,
                interpret: bool = False):
    """h (T, d), w (d, V), a (d, r), b (r, V) -> logits (T, V) float32."""
    T, d = h.shape
    V = w.shape[1]
    r = a.shape[1]
    bt = min(block_t, max(8, T))
    bv = min(block_v, V)
    Tp = -(-T // bt) * bt
    Vp = -(-V // bv) * bv
    if Tp != T:
        h = jnp.pad(h, ((0, Tp - T), (0, 0)))
    if Vp != V:
        w = jnp.pad(w, ((0, 0), (0, Vp - V)))
        b = jnp.pad(b, ((0, 0), (0, Vp - V)))

    out = pl.pallas_call(
        functools.partial(_kernel, gamma=gamma),
        grid=(Tp // bt, Vp // bv),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bv), lambda i, j: (0, j)),
            pl.BlockSpec((d, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, bv), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bt, bv), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Tp, Vp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bt, r), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(h, w, a, b)
    return out[:T, :V]
