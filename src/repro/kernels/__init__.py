"""Pallas TPU kernels for DVI's compute hot-spots.

Each kernel ships three artifacts: <name>.py (pl.pallas_call + BlockSpec),
ops.py (jit'd wrapper, interpret-mode on CPU), ref.py (pure-jnp oracle).
"""
