"""Jit'd public wrappers for the Pallas kernels — the single dispatch point.

On CPU (this container) kernels run in ``interpret=True`` mode — the kernel
body executes in Python for bit-faithful validation against the ref.py
oracles; on a real TPU backend the same calls compile to Mosaic.  Set
``REPRO_FORCE_INTERPRET=0`` to force compiled mode.

``decode_attention`` dispatches across the three implementations by
argument/`impl`: the pure-jnp oracle (``impl="ref"``), the contiguous
flash-decode Pallas kernel (default), and the paged block-table kernel
(``paged_decode_attention`` / ``impl="paged"`` spelled as the dedicated
entry point, since the paged cache has different operands).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import \
    decode_attention_pallas as _decode_attention
from repro.kernels.lora_logits import lora_logits as _lora_logits
from repro.kernels.paged_decode_attention import \
    paged_decode_attention as _paged_decode_attention
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan
from repro.kernels.verify_argmax import verify_argmax as _verify_argmax


def _interpret() -> bool:
    env = os.environ.get("REPRO_FORCE_INTERPRET")
    if env is not None:
        return env not in ("0", "false")
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("block_t", "block_v"))
def verify_argmax(h, w, block_t: int = 128, block_v: int = 2048):
    return _verify_argmax(h, w, block_t=block_t, block_v=block_v,
                          interpret=_interpret())


@partial(jax.jit, static_argnames=("gamma", "block_t", "block_v"))
def lora_logits(h, w, a, b, gamma: float, block_t: int = 128,
                block_v: int = 2048):
    return _lora_logits(h, w, a, b, gamma, block_t=block_t, block_v=block_v,
                        interpret=_interpret())


@partial(jax.jit, static_argnames=("block_s", "impl"))
def decode_attention(q, k, v, lengths, block_s: int = 512, impl: str = "pallas"):
    """Contiguous-cache flash decode.  impl: "pallas" (default; interpret
    mode on CPU) or "ref" (pure-jnp oracle)."""
    if impl == "ref":
        return ref.ref_decode_attention(q, k, v, lengths)
    return _decode_attention(q, k, v, lengths, block_s=block_s,
                             interpret=_interpret())


@partial(jax.jit, static_argnames=("impl",))
def paged_decode_attention(q, k_pages, v_pages, lengths, block_tables,
                           page_counts=None, impl: str = "pallas"):
    """Paged-cache flash decode: K/V tiles gathered through the per-lane
    block table (see repro.serving.kv_pool for the layout).  Lanes early-out
    of the page sweep after `page_counts` pages (default: just enough to
    cover `lengths`)."""
    if impl == "ref":
        return ref.ref_paged_decode_attention(q, k_pages, v_pages, lengths,
                                              block_tables,
                                              page_counts=page_counts)
    return _paged_decode_attention(q, k_pages, v_pages, lengths, block_tables,
                                   page_counts=page_counts,
                                   interpret=_interpret())


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(xh, Bc, Cc, dt, A, chunk: int = 128):
    return _ssd_scan(xh, Bc, Cc, dt, A, chunk, interpret=_interpret())


__all__ = ["verify_argmax", "lora_logits", "decode_attention",
           "paged_decode_attention", "ssd_scan", "ref"]
