"""Pytree checkpointing to .npz (no orbax dependency).

Keys are '/'-joined pytree paths; dtypes/shapes restored exactly.  For DVI
serving, ``save_lora`` checkpoints ONLY the trainable adapters + trainer
scalars — the artifact of continual learning is a few MB regardless of
backbone size (the paper's "single-model deployment" story: the backbone
checkpoint never changes).
"""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))


def load_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure (and dtypes) of `like`."""
    with np.load(path if path.endswith(".npz") else path + ".npz") as data:
        flat_like = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for pathk, leaf in flat_like[0]:
            key = "/".join(
                str(p.key) if hasattr(p, "key") else str(p.idx) for p in pathk)
            arr = data[key]
            assert arr.shape == leaf.shape, f"{key}: {arr.shape} vs {leaf.shape}"
            leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(flat_like[1], leaves)


def save_lora(path: str, dvi_params: dict, step=0, baseline=0.0) -> None:
    save_checkpoint(path, {"dvi": dvi_params,
                           "meta": {"step": jnp.int32(step),
                                    "baseline": jnp.float32(baseline)}})


def load_lora(path: str, like_dvi: dict):
    like = {"dvi": like_dvi, "meta": {"step": jnp.int32(0),
                                      "baseline": jnp.float32(0.0)}}
    tree = load_checkpoint(path, like)
    return tree["dvi"], int(tree["meta"]["step"]), float(tree["meta"]["baseline"])
