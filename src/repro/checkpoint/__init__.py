from repro.checkpoint.ckpt import (save_checkpoint, load_checkpoint,
                                   save_lora, load_lora)

__all__ = ["save_checkpoint", "load_checkpoint", "save_lora", "load_lora"]
