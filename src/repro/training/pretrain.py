"""Backbone pretraining (substrate) + the DVI drafter train step.

``make_pretrain_step`` — full-model next-token cross-entropy with AdamW;
used to give tiny backbones real predictive structure before DVI online
learning (and as the generic ``--step pretrain`` dry-run workload).

``make_dvi_train_step`` — the paper's training workload (the `train_4k`
dry-run shape): forward h_k -> h_L once, composite KL->RL loss, gradients
and Adam state for the LoRA adapters ONLY (the backbone never sees a
gradient — that is what makes training-aware serving cheap).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import losses as losses_mod
from repro.models.model import Model
from repro.optim import adamw_init, adamw_update


def lm_loss(model: Model, params, tokens, aux_inputs=None, remat=False):
    logits, aux = model.forward_train(params, tokens, aux_inputs, remat=remat)
    V = model.cfg.vocab_size
    P = model.cfg.vision.num_patches if model.cfg.vision is not None else 0
    logits = logits[:, P:, :]
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean() + aux, {"nll": nll.mean(), "aux": aux}


def make_pretrain_step(model: Model, lr, remat: bool = False,
                       donate: bool = True):
    """lr: float or schedule fn(step)->lr."""
    lr_fn = lr if callable(lr) else (lambda s: lr)

    @partial(jax.jit, donate_argnums=(0, 1) if donate else ())
    def step(params, opt_state, tokens, aux_inputs=None):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(model, p, tokens, aux_inputs, remat),
            has_aux=True)(params)
        params, opt_state, gnorm = adamw_update(
            params, grads, opt_state, lr_fn(opt_state["step"]),
            weight_decay=0.01)
        metrics["loss"] = loss
        metrics["gnorm"] = gnorm
        return params, opt_state, metrics

    return step


def pretrain(model: Model, params, data_stream, *, lr=1e-3, remat=False,
             log_every: int = 0, aux_inputs_fn=None):
    """Train the backbone over a stream of (B, T) token batches."""
    opt_state = adamw_init(params)
    step_fn = make_pretrain_step(model, lr, remat)
    losses = []
    for i, tokens in enumerate(data_stream):
        aux = aux_inputs_fn(tokens) if aux_inputs_fn else None
        params, opt_state, metrics = step_fn(params, opt_state, tokens, aux)
        losses.append(float(metrics["loss"]))
        if log_every and (i + 1) % log_every == 0:
            print(f"[pretrain] step {i+1}: loss={losses[-1]:.4f}")
    return params, losses


def make_dvi_train_step(model: Model, lr: float = 1e-3, mode: str = "full",
                        remat: bool = False):
    """The paper's drafter-update step over a token batch (train_4k shape)."""

    @jax.jit
    def step(params, dvi_params, opt_state, tokens, t, baseline,
             aux_inputs=None):
        def loss_fn(dp):
            return losses_mod.dense_train_losses(
                model, params, dp, tokens, t, baseline, mode, aux_inputs,
                remat)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(dvi_params)
        dvi_params, opt_state, gnorm = adamw_update(dvi_params, grads,
                                                    opt_state, lr)
        ema = model.cfg.dvi.baseline_ema
        baseline = ema * baseline + (1 - ema) * metrics["acc_rate"]
        metrics["gnorm"] = gnorm
        return dvi_params, opt_state, baseline, metrics

    return step
