from repro.training.pretrain import (lm_loss, make_pretrain_step, pretrain,
                                     make_dvi_train_step)

__all__ = ["lm_loss", "make_pretrain_step", "pretrain", "make_dvi_train_step"]
