"""Minimal AdamW + schedules + global-norm clipping (no optax dependency).

Pytree-generic; state is {"m", "v", "step"}.  Used for both the DVI LoRA
drafter updates (tiny: rank x (d + V) params) and full-backbone pretraining.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.int32(0)}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def adamw_update(params, grads, state, lr, *, b1=0.9, b2=0.999, eps=1e-8,
                 weight_decay=0.0, max_norm=1.0):
    """Returns (new_params, new_state, gnorm).  lr may be traced."""
    grads, gnorm = clip_by_global_norm(grads, max_norm)
    step = state["step"] + 1
    sf = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** sf
    bc2 = 1.0 - b2 ** sf

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm


def cosine_schedule(base_lr: float, total_steps: int, min_frac: float = 0.1):
    def f(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(math.pi * frac)))
    return f


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                         min_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), min_frac)
    def f(step):
        w = jnp.clip(step / max(warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, base_lr * w, cos(step - warmup))
    return f
