"""DVI composite training objective (paper §3.4).

    L_fast  = lambda_pg * L_pg + lambda_kl * KL(p_theta || p_phi^tau)
              + w_ce * L_CE - w_ent * H[p_theta]
    L_policy = w_rl * E[-(r - b) log p_theta(a|s)] + beta(t) KL(p_theta||p_phi)

* L_pg: reward-masked CE over *accepted* positions only (credit where
  speculation succeeded).
* L_CE: CE to the verifier's greedy token over all logged positions
  (accepted + first reject) — on accepts this coincides with L_pg's target;
  on the first reject it teaches the correction token.
* KL: online distillation to the temperature-softened frozen verifier.
* L_policy: REINFORCE with an EMA-of-rewards baseline over accepted +
  first-reject tuples (counterfactual positions are never logged).

Ablation modes (paper §4.3): 'kl' / 'pg' / 'ce' single-term variants,
'full' = the KL->RL schedule.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import schedule as sched
from repro.core.lora import draft_logits
from repro.models.layers import rms_norm
from repro.models.model import Model


def verifier_logits(model: Model, params: dict, h_L: jax.Array) -> jax.Array:
    """Frozen target-path logits from buffered deep hidden states."""
    return model.logits(params, h_L).astype(jnp.float32)


def loss_terms(model: Model, params: dict, dvi_params: dict, batch: dict):
    """Per-term losses on a buffer minibatch.  Returns dict of scalars."""
    cfg = model.cfg
    tau = cfg.dvi.kd_temperature
    mask = batch["mask"]                                   # (N,) 0/1
    r = batch["reward"]                                    # (N,) 1 accept / 0 first-reject

    logits_t = draft_logits(model, params, dvi_params, batch["h_k"])   # (N,V)
    logits_v = verifier_logits(model, params, batch["h_L"])            # (N,V)

    logp_t = jax.nn.log_softmax(logits_t, axis=-1)
    p_t = jnp.exp(logp_t)
    logp_v_tau = jax.nn.log_softmax(logits_v / tau, axis=-1)
    logp_v = jax.nn.log_softmax(logits_v, axis=-1)

    denom = jnp.maximum(mask.sum(), 1.0)
    acc_denom = jnp.maximum((mask * r).sum(), 1.0)

    # KL(p_theta || p_phi^tau), dense online distillation
    kl_tau = jnp.sum(p_t * (logp_t - logp_v_tau), axis=-1)
    kl_tau = (kl_tau * mask).sum() / denom
    kl_1 = jnp.sum(p_t * (logp_t - logp_v), axis=-1)
    kl_1 = (kl_1 * mask).sum() / denom

    # reward-masked CE on accepted actions
    act_logp = jnp.take_along_axis(logp_t, batch["action"][:, None], axis=-1)[:, 0]
    l_pg = -(act_logp * r * mask).sum() / acc_denom

    # CE to the verifier greedy token (accepted + first reject)
    y_star = jnp.argmax(logits_v, axis=-1)
    star_logp = jnp.take_along_axis(logp_t, y_star[:, None], axis=-1)[:, 0]
    l_ce = -(star_logp * mask).sum() / denom

    # entropy bonus
    ent = (-jnp.sum(p_t * logp_t, axis=-1) * mask).sum() / denom

    # acceptance rate of this batch (diagnostic + EMA baseline source)
    acc_rate = (r * mask).sum() / denom
    return {"kl_tau": kl_tau, "kl_1": kl_1, "l_pg": l_pg, "l_ce": l_ce,
            "entropy": ent, "act_logp": act_logp, "acc_rate": acc_rate,
            "mask": mask, "reward": r}


def composite_loss(dvi_params: dict, model: Model, params: dict,
                   batch: dict, fresh: Optional[dict], t, baseline,
                   mode: str = "full"):
    """Full DVI objective at optimizer step t.  Returns (loss, metrics)."""
    cfg = model.cfg
    dvi = cfg.dvi
    terms = loss_terms(model, params, dvi_params, batch)
    lam_pg, lam_kl = sched.lambda_schedule(t, dvi)
    gate = sched.policy_gate(t, dvi)
    beta = sched.beta_schedule(t, dvi)
    pg_on = jnp.float32(0.0)     # on-policy PG term; stays 0 until it fires

    if mode == "kl":
        loss = terms["kl_tau"]
    elif mode == "pg":
        # pure on-policy REINFORCE (no KD) — paper ablation 2
        adv = (terms["reward"] - baseline) * terms["mask"]
        loss = -(adv * terms["act_logp"]).sum() / jnp.maximum(terms["mask"].sum(), 1.0)
    elif mode == "ce":
        loss = terms["l_pg"]          # reward-masked CE only — paper ablation 3
    else:
        loss = (lam_pg * terms["l_pg"] + lam_kl * terms["kl_tau"]
                + dvi.w_ce * terms["l_ce"] - dvi.w_ent * terms["entropy"])
        if fresh is not None:
            ft = loss_terms(model, params, dvi_params, fresh)
            adv = (ft["reward"] - baseline) * ft["mask"]
            pg_on = -(adv * ft["act_logp"]).sum() / jnp.maximum(ft["mask"].sum(), 1.0)
            loss = loss + gate * (dvi.w_rl * pg_on + beta * ft["kl_1"])

    # all three DVI components (KL / reward-masked CE / on-policy PG) plus
    # the schedule state are always present — dvi_train_* telemetry reads
    # these keys unconditionally regardless of mode/ablation
    metrics = {"loss": loss, "kl": terms["kl_tau"], "l_pg": terms["l_pg"],
               "l_ce": terms["l_ce"], "entropy": terms["entropy"],
               "acc_rate": terms["acc_rate"], "lam_pg": lam_pg,
               "lam_kl": lam_kl, "pg_on": pg_on, "beta": beta, "gate": gate}
    return loss, metrics


def dense_train_losses(model: Model, params: dict, dvi_params: dict,
                       tokens: jax.Array, t, baseline, mode: str = "full",
                       aux_inputs=None, remat: bool = False,
                       max_positions: int = 8192):
    """Teacher-forced batch variant of the DVI objective (the `train_4k`
    workload): one full forward computes h_k and h_L at every position,
    position-wise accept = (draft greedy == verifier greedy), and the same
    composite loss applies with the dense accept mask as reward.

    Positions are stride-subsampled to <= max_positions before the (N, V)
    logits — mirroring the paper's minibatch-from-buffer updates and keeping
    the loss head O(max_positions x V) regardless of batch x seq (a 1M-token
    batch with a 128k vocab would otherwise need a 0.5 PB logits tensor).
    Gradients flow ONLY to the LoRA adapters: the backbone forward is
    activation-free for backward purposes (no remat stash needed)."""
    cfg = model.cfg
    k = cfg.dvi.split_layer
    enc = model.encode(params, aux_inputs) if cfg.encoder is not None else None
    x = model.embed(params, tokens, aux_inputs)
    x = jax.lax.stop_gradient(x)
    h_k, _, _ = model.hidden(params, x, 0, k, enc_out=enc, remat=remat,
                             prefix_len=model._prefix_len(aux_inputs))
    h_L, _, aux = model.hidden(params, h_k, k, None, enc_out=enc, remat=remat,
                               prefix_len=model._prefix_len(aux_inputs))
    B, T, d = h_k.shape
    # position i's tuple: (h_k[i], predicts token i+1); drop the last position
    hk = h_k[:, :-1].reshape(-1, d)
    hL = h_L[:, :-1].reshape(-1, d)
    N = hk.shape[0]
    if N > max_positions:
        stride = -(-N // max_positions)
        hk = hk[::stride]
        hL = hL[::stride]
    hk = jax.lax.stop_gradient(hk)
    hL = jax.lax.stop_gradient(hL)
    logits_t = draft_logits(model, params, dvi_params, hk)
    logits_v = verifier_logits(model, params, hL)
    a = jnp.argmax(logits_t, axis=-1)
    y = jnp.argmax(logits_v, axis=-1)
    reward = (a == y).astype(jnp.float32)
    batch = {"h_k": hk, "h_L": hL, "action": a, "reward": reward,
             "mask": jnp.ones_like(reward)}
    loss, metrics = composite_loss(dvi_params, model, params, batch, None, t,
                                   baseline, mode)
    return loss, metrics
