"""Training-aware control schedules.

Two controllers live here:

1. The KL->RL annealing schedule (paper §3.4):

    (lambda_pg, lambda_kl)(t) =
        (0, lambda_0)                                   t < T_warmup
        linear ramp to (lambda_pg_max, lambda_kl_min)   T_warmup <= t < T_warmup + T_ramp
        (lambda_pg_max, lambda_kl_min)                  after

   beta(t) for the on-policy correction decays from beta0 to beta_min.

2. The per-lane **speculation-depth controller** (`DepthConfig` /
   `depth_update`): the verifier's accept/reject stream steers not just the
   drafter weights but the speculative machinery itself.  Each lane tracks
   an EMA of its per-block acceptance fraction ``r = m / k`` and adjusts its
   depth AIMD-style — additive +1 when the EMA clears ``hi`` (the lane is
   wasting verifier bandwidth on too-short blocks), multiplicative halving
   when it drops below ``lo`` (the lane is wasting draft compute on tokens
   that get rejected).  Every change arms a ``cooldown`` so the EMA can
   re-settle at the new depth before the next move.  ``depth_update`` is
   pure jnp and runs INSIDE the fused superstep's while-loop, so adapting
   depth costs zero extra host syncs; depth therefore only ever changes at
   speculative-block boundaries (the adaptive-depth contract in ROADMAP).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.configs.base import DVIConfig


def lambda_schedule(t, dvi: DVIConfig):
    """t: scalar (traced ok).  Returns (lambda_pg, lambda_kl) float32."""
    t = jnp.asarray(t, jnp.float32)
    frac = jnp.clip((t - dvi.warmup_steps) / max(dvi.ramp_steps, 1), 0.0, 1.0)
    lam_pg = frac * dvi.lambda_pg_max
    lam_kl = dvi.lambda_kl0 - frac * (dvi.lambda_kl0 - dvi.lambda_kl_min)
    return lam_pg, lam_kl


def beta_schedule(t, dvi: DVIConfig):
    t = jnp.asarray(t, jnp.float32)
    decay = jnp.exp(-t / max(dvi.beta_decay_steps, 1))
    return dvi.beta_min + (dvi.beta0 - dvi.beta_min) * decay


def policy_gate(t, dvi: DVIConfig):
    """On-policy correction is off during warmup, ramps in with lambda_pg."""
    lam_pg, _ = lambda_schedule(t, dvi)
    return lam_pg / max(dvi.lambda_pg_max, 1e-9)


def phase_info(t: int, dvi: DVIConfig) -> dict:
    """Host-side, math-only mirror of the KL->RL schedules at step `t` —
    for telemetry (the serving hot path must not touch the device or build
    jnp graphs just to report where the schedule sits).  Returns
    ``{phase, phase_name, lambda_pg, lambda_kl, beta, gate}`` with
    phase 0=warmup, 1=ramp, 2=rl.  Kept numerically identical to
    ``lambda_schedule`` / ``beta_schedule`` / ``policy_gate`` above
    (asserted in tests/test_telemetry.py)."""
    import math as _math
    t = float(t)
    frac = min(max((t - dvi.warmup_steps) / max(dvi.ramp_steps, 1), 0.0), 1.0)
    lam_pg = frac * dvi.lambda_pg_max
    lam_kl = dvi.lambda_kl0 - frac * (dvi.lambda_kl0 - dvi.lambda_kl_min)
    beta = dvi.beta_min + (dvi.beta0 - dvi.beta_min) * _math.exp(
        -t / max(dvi.beta_decay_steps, 1))
    phase = 0 if t < dvi.warmup_steps else (1 if frac < 1.0 else 2)
    return {"phase": phase,
            "phase_name": ("warmup", "ramp", "rl")[phase],
            "lambda_pg": lam_pg, "lambda_kl": lam_kl, "beta": beta,
            "gate": lam_pg / max(dvi.lambda_pg_max, 1e-9)}


# ---------------------------------------------------------------------------
# Per-lane adaptive speculation depth (acceptance-EMA target tracking, AIMD)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DepthConfig:
    """Knobs for the per-lane depth controller.

    ``k_min >= 1``: a lane at depth 0 would draft nothing, observe no
    accept/reject signal, and could never recover — the controller refuses
    degenerate floors.  ``cooldown >= 1`` bounds how fast depth can move:
    at most one +1 rise per ``cooldown`` blocks, which is what lets the
    serving engine put a hard upper bound on a lane's depth over a
    ``sync_every``-block superstep (see ``max_depth_rises``) and provision
    KV pages for exactly that bound."""
    k_min: int = 1
    k_max: int = 4
    k_init: int = 4              # depth for freshly admitted lanes
    ema_alpha: float = 0.25      # acceptance-EMA step per block
    hi: float = 0.70             # EMA >= hi (cooled down): k += 1
    lo: float = 0.35             # EMA <= lo (cooled down): k = max(k//2, k_min)
    cooldown: int = 4            # blocks between depth changes per lane
    ema_init: float = 0.5        # neutral start between lo and hi

    def __post_init__(self):
        if not 1 <= self.k_min <= self.k_init <= self.k_max:
            raise ValueError(
                f"need 1 <= k_min <= k_init <= k_max, got "
                f"({self.k_min}, {self.k_init}, {self.k_max})")
        if self.cooldown < 1:
            raise ValueError("cooldown must be >= 1 (bounds depth slew rate)")
        if not 0.0 <= self.lo < self.hi <= 1.0:
            raise ValueError(f"need 0 <= lo < hi <= 1, got ({self.lo}, {self.hi})")


def init_depth_state(dc: DepthConfig, n: int):
    """Fresh controller state for `n` lanes: (k, ema, cool) arrays."""
    return (jnp.full((n,), dc.k_init, jnp.int32),
            jnp.full((n,), dc.ema_init, jnp.float32),
            jnp.zeros((n,), jnp.int32))


def depth_update(dc: DepthConfig, k, ema, cool, m, live, k_hi=None):
    """ONE in-graph controller step at a block boundary.

    k/ema/cool: (B,) per-lane state; m: (B,) accepted drafted tokens this
    block (the verifier's signal); live: (B,) bool — masked lanes (done,
    mid-prefill, free slots) keep their state frozen.  `k_hi`: optional
    per-lane ceiling below ``k_max`` — the serving engine passes the depth
    it provisioned KV pages for, so an in-graph rise can never outrun the
    pool (reservation soundness does not depend on the controller).
    Returns the new (k, ema, cool)."""
    k_hi = jnp.asarray(dc.k_max if k_hi is None else k_hi, jnp.int32)
    r = m.astype(jnp.float32) / jnp.maximum(k, 1).astype(jnp.float32)
    ema2 = jnp.where(live, ema + dc.ema_alpha * (r - ema), ema)
    cool2 = jnp.where(live, jnp.maximum(cool - 1, 0), cool)
    ready = live & (cool2 == 0)
    up = ready & (ema2 >= dc.hi) & (k < k_hi)
    dn = ready & (ema2 <= dc.lo) & (k > dc.k_min)
    k2 = jnp.where(up, jnp.minimum(k + 1, k_hi),
                   jnp.where(dn, jnp.maximum(k // 2, dc.k_min), k))
    cool2 = jnp.where(up | dn, dc.cooldown, cool2)
    return k2, ema2, cool2


def max_depth_rises(dc: DepthConfig, steps: int, cool0: int) -> int:
    """Host-side upper bound on the +1 depth rises ``depth_update`` can make
    over `steps` blocks for a lane entering with cooldown `cool0`.  The
    engine's page-growth pass uses ``k + max_depth_rises`` as the lane's
    worst-case depth for the next superstep (and passes the same bound back
    as ``k_hi``, making the two mutually consistent by construction)."""
    first = max(int(cool0) - 1, 0)       # cool decrements before the gate
    if first >= steps:
        return 0
    return 1 + (steps - 1 - first) // max(dc.cooldown, 1)
