"""KL->RL annealing schedule (paper §3.4).

    (lambda_pg, lambda_kl)(t) =
        (0, lambda_0)                                   t < T_warmup
        linear ramp to (lambda_pg_max, lambda_kl_min)   T_warmup <= t < T_warmup + T_ramp
        (lambda_pg_max, lambda_kl_min)                  after

beta(t) for the on-policy correction decays from beta0 to beta_min.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import DVIConfig


def lambda_schedule(t, dvi: DVIConfig):
    """t: scalar (traced ok).  Returns (lambda_pg, lambda_kl) float32."""
    t = jnp.asarray(t, jnp.float32)
    frac = jnp.clip((t - dvi.warmup_steps) / max(dvi.ramp_steps, 1), 0.0, 1.0)
    lam_pg = frac * dvi.lambda_pg_max
    lam_kl = dvi.lambda_kl0 - frac * (dvi.lambda_kl0 - dvi.lambda_kl_min)
    return lam_pg, lam_kl


def beta_schedule(t, dvi: DVIConfig):
    t = jnp.asarray(t, jnp.float32)
    decay = jnp.exp(-t / max(dvi.beta_decay_steps, 1))
    return dvi.beta_min + (dvi.beta0 - dvi.beta_min) * decay


def policy_gate(t, dvi: DVIConfig):
    """On-policy correction is off during warmup, ramps in with lambda_pg."""
    lam_pg, _ = lambda_schedule(t, dvi)
    return lam_pg / max(dvi.lambda_pg_max, 1e-9)
