"""Online DVI trainer: closes the loop between speculation and learning.

Mirrors the paper's protocol: stream prompts one batch at a time, generate
with tuple logging, then perform small frequent LoRA updates from the
replay buffer (paper: 2000 prompts -> 2000 optimizer steps, each prompt
seen once).  The update is data-parallel-friendly: gradients exist only
for the LoRA adapters (rank x (d + V)), so the all-reduce is a few MB.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import buffer as buffer_mod
from repro.core import losses as losses_mod
from repro.core import spec as spec_mod
from repro.models.model import Model
from repro.optim import adamw_init, adamw_update


@dataclass
class OnlineTrainerState:
    dvi_params: dict
    opt_state: dict
    buf: dict
    baseline: jax.Array          # EMA of recent rewards (variance reduction)
    step: jax.Array              # optimizer step t (drives the KL->RL schedule)


def init_trainer(model: Model, key, slots: int = 0) -> OnlineTrainerState:
    from repro.core.lora import init_draft_params
    dvi_params = init_draft_params(key, model.cfg)
    return OnlineTrainerState(
        dvi_params=dvi_params,
        opt_state=adamw_init(dvi_params),
        buf=buffer_mod.init_buffer(model.cfg, slots),
        baseline=jnp.float32(0.0),
        step=jnp.int32(0),
    )


def make_update_fn(model: Model, mode: str = "full", lr: float = 1e-3):
    """Jitted: one minibatch LoRA update from the buffer."""
    cfg = model.cfg
    dvi = cfg.dvi

    @jax.jit
    def update(params, dvi_params, opt_state, buf, baseline, step, key):
        batch = buffer_mod.sample(buf, key, dvi.batch_size)
        fresh = buffer_mod.fresh_batch(buf, dvi.batch_size) if mode == "full" else None

        def loss_fn(dp):
            return losses_mod.composite_loss(dp, model, params, batch, fresh,
                                             step, baseline, mode)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(dvi_params)
        new_dvi, new_opt, gnorm = adamw_update(dvi_params, grads, opt_state, lr)
        # EMA baseline over observed batch acceptance
        new_baseline = (dvi.baseline_ema * baseline
                        + (1 - dvi.baseline_ema) * metrics["acc_rate"])
        metrics["gnorm"] = gnorm
        # acceptance-EMA baseline around the update (dvi_train_* telemetry)
        metrics["baseline_before"] = baseline
        metrics["baseline_after"] = new_baseline
        metrics["buffer_count"] = buf["count"]
        return new_dvi, new_opt, new_baseline, metrics

    return update


def online_loop(model: Model, params: dict, prompt_stream, state: OnlineTrainerState,
                *, max_new: int = 64, updates_per_batch: int = 1,
                mode: str = "full", lr: float = 1e-3, key=None,
                log_every: int = 0, aux_inputs_fn=None):
    """Run the paper's generate-and-improve loop over a prompt stream.

    prompt_stream: iterable of (B, Tp) int32 arrays (equal Tp per batch).
    Returns (state, history) where history logs per-batch acceptance."""
    key = key if key is not None else jax.random.PRNGKey(0)
    update = make_update_fn(model, mode, lr)
    history = {"acc_rate": [], "block_acc": [], "mat": [], "loss": [], "kl": []}

    @jax.jit
    def gen(params, dvi_params, prompts, buf, aux):
        return spec_mod.speculative_generate(
            model, params, dvi_params, prompts, max_new,
            collect=True, buf=buf, aux_inputs=aux)

    for bi, prompts in enumerate(prompt_stream):
        aux = aux_inputs_fn(prompts) if aux_inputs_fn else None
        res = gen(params, state.dvi_params, prompts, state.buf, aux)
        state.buf = res.buffer
        block_acc = float(res.accepted_drafts) / max(float(res.drafted), 1.0)
        mat = float(res.committed) / max(float(res.blocks), 1.0)

        for _ in range(updates_per_batch):
            key, sub = jax.random.split(key)
            state.dvi_params, state.opt_state, state.baseline, metrics = update(
                params, state.dvi_params, state.opt_state, state.buf,
                state.baseline, state.step, sub)
            state.step = state.step + 1

        history["block_acc"].append(block_acc)
        history["mat"].append(mat)
        history["acc_rate"].append(float(metrics["acc_rate"]))
        history["loss"].append(float(metrics["loss"]))
        history["kl"].append(float(metrics["kl"]))
        if log_every and (bi + 1) % log_every == 0:
            print(f"[online] batch {bi+1}: block_acc={block_acc:.3f} "
                  f"MAT={mat:.2f} loss={history['loss'][-1]:.4f} "
                  f"kl={history['kl'][-1]:.4f} step={int(state.step)}")
    return state, history
