"""Draft -> Verify -> commit: the self-speculative decoding engine (paper §3.2-3.3).

One speculative *block* at committed length t (all shapes static, batched,
runs inside ``jax.lax.while_loop``):

1. **Draft** — K+1 shallow feeds through layers [0, k).  Feed j embeds the
   pending token, produces ``h_k(t+j)``, and the LoRA draft head greedily
   proposes the next token.  Shallow caches advance eagerly; stateful
   mixers' per-feed states are stacked for later rollback-by-selection.
2. **Verify** — ONE deep pass of layers [k, L) over the h_k block (this is
   where self-speculation amortizes the deep compute), giving verifier
   greedy tokens ``y*(t+1 .. t+K+1)``.
3. **Commit** — the longest agreeing prefix m plus the verifier's
   correction/bonus token: m+1 tokens ∈ [1, K+1] per block.  The committed
   stream is *exactly* the target path's greedy decoding (tested as a
   property).  Accept/reject outcomes for drafted positions 1..K are logged
   to the replay buffer (r=1 accepted, r=0 first reject, counterfactuals
   excluded).

``k_spec=0`` degenerates to plain autoregressive decoding of the target
path through the same code path (the AR baseline).

``spec_block_step`` is the single owner of the block above; it is composed
two ways: ``speculative_generate`` loops it inside ``jax.lax.while_loop``
(batch decoding with tuple logging), and the continuous-batching
``ServingEngine`` interleaves it with per-slot cache surgery (admission /
retirement) so ragged traffic shares one persistent decode batch.

The cache may be contiguous (``init_cache``) or paged
(``init_paged_cache``): a paged cache carries its block table inside the
pytree (``cache["tbl"]``), so every draft feed and the deep verify pass
transparently read/write KV through the page indirection — the block-step
logic is layout-agnostic, and speculative rollback stays "truncate the
lane length" in both layouts (see repro.serving.kv_pool).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import buffer as buffer_mod
from repro.core import schedule as schedule_mod
from repro.core.lora import draft_logits
from repro.models import transformer as tfm
from repro.models.model import Model


class GenResult(NamedTuple):
    tokens: jax.Array          # (B, total) committed stream (prompt + gen)
    lengths: jax.Array         # (B,) valid token count
    blocks: jax.Array          # scalar: total verification steps
    committed: jax.Array       # scalar: total committed tokens (gen only)
    accepted_drafts: jax.Array # scalar: total accepted drafted tokens
    drafted: jax.Array         # scalar: total drafted tokens (valid blocks * K)
    buffer: Optional[dict]


class SuperstepResult(NamedTuple):
    """Result of a fused run of up to ``steps`` speculative blocks (one
    device dispatch, one host sync).  ``gen_buf[:, :gen_count]`` holds the
    tokens committed THIS superstep (per lane, already EOS/budget-capped);
    the per-lane counters summarize what the host would have accumulated
    block by block."""
    pending: jax.Array         # (B,) next pending token
    done: jax.Array            # (B,) bool — includes in-graph EOS/budget exits
    gen_buf: jax.Array         # (B, steps*(K+1)) committed tokens, capped
    gen_count: jax.Array       # (B,) valid prefix length of gen_buf
    lane_blocks: jax.Array     # (B,) blocks the lane was live for
    lane_committed: jax.Array  # (B,) cache advance (sum of accepts)
    lane_accepted: jax.Array   # (B,) accepted drafted tokens (sum of m)
    lane_drafted: jax.Array    # (B,) drafted tokens (sum of live-block depths)
    k_lane: jax.Array          # (B,) speculation depth after the last block
    accept_ema: jax.Array      # (B,) depth controller acceptance EMA
    k_cool: jax.Array          # (B,) depth controller cooldown counter
    accept_hist: jax.Array     # (K+1,) live blocks by accepted drafts m
    depth_hist: jax.Array      # (K+1,) live blocks by depth k they ran at
    cache: dict                # advanced decode cache
    buffer: Optional[dict]     # replay buffer with this superstep's tuples
    key: jax.Array             # threaded PRNG key (sampling path)


class BlockStep(NamedTuple):
    """Result of ONE speculative block (draft K+1, verify once, commit m+1)."""
    pending: jax.Array         # (B,) next pending token (unchanged where done)
    commit_vec: jax.Array      # (B, K+1) committed tokens (first `accept` valid)
    accept: jax.Array          # (B,) committed count: m+1 live, 0 where done
    m: jax.Array               # (B,) accepted drafted tokens this block
    cache: dict                # advanced decode cache
    hk_blk: jax.Array          # (B, K+1, d) draft-path hiddens (tuple logging)
    hL_blk: jax.Array          # (B, K+1, d) target-path hiddens
    d_blk: jax.Array           # (B, K+1) drafted tokens
    key: jax.Array             # threaded PRNG key (sampling path)


def _restack_cands(cand_stack):
    """scan-stacked shallow candidates (K+1, n, B, 1, ...) -> (n, B, K+1, ...)."""
    return jax.tree.map(lambda a: jnp.moveaxis(a.squeeze(3), 0, 2), cand_stack)


# ---------------------------------------------------------------------------
# Beyond-paper: temperature sampling with lossless rejection verification
# (Leviathan'23 speculative *sampling*; the paper evaluates greedy only)
# ---------------------------------------------------------------------------

def rejection_commit(key, d_blk, dprobs, vprobs, k_lane=None):
    """Speculative-sampling accept/reject (exact target distribution).

    d_blk (B, K+1) drafted tokens (position K is the bonus feed, unused for
    acceptance); dprobs/vprobs (B, K+1, V) drafter/verifier distributions.
    Accept drafted token i while u_i < p(d_i)/q(d_i); at the first reject
    emit a sample from norm(max(p - q, 0)); if all K accepted emit a bonus
    sample from p at position K.  Returns (m, correction (B,)).

    k_lane: optional (B,) per-lane speculation depth <= K.  Drafted
    positions at or beyond a lane's depth are forced-rejected (they were
    never really proposed), and the bonus branch fires at m == k_lane —
    exactness is per lane: each lane's stream is distributed as target
    sampling at ITS depth."""
    B, K1, V = dprobs.shape
    K = K1 - 1
    ku, kr = jax.random.split(key)
    u = jax.random.uniform(ku, (B, K))
    p_at = jnp.take_along_axis(vprobs[:, :K], d_blk[:, :K, None], -1)[..., 0]
    q_at = jnp.take_along_axis(dprobs[:, :K], d_blk[:, :K, None], -1)[..., 0]
    ratio = p_at / jnp.maximum(q_at, 1e-20)
    ok = (u < ratio).astype(jnp.int32)
    if k_lane is not None:
        ok = ok * (jnp.arange(K)[None, :] < k_lane[:, None]).astype(jnp.int32)
    m = jnp.sum(jnp.cumprod(ok, axis=1), axis=1)                  # (B,)

    # correction distribution at position m: residual (reject) or p (bonus)
    pm = jnp.take_along_axis(vprobs, m[:, None, None], axis=1)[:, 0]   # (B,V)
    qm = jnp.take_along_axis(dprobs, m[:, None, None], axis=1)[:, 0]
    resid = jnp.maximum(pm - qm, 0.0)
    rsum = resid.sum(-1, keepdims=True)
    resid = jnp.where(rsum > 1e-20, resid / jnp.maximum(rsum, 1e-20), pm)
    k_eff = K if k_lane is None else k_lane
    dist = jnp.where((m == k_eff)[:, None], pm, resid)
    correction = jax.random.categorical(kr, jnp.log(jnp.maximum(dist, 1e-30)))
    return m, correction.astype(jnp.int32)


def spec_block_step(model: Model, params: dict, dvi_params: dict,
                    pending: jax.Array, cache: dict, *,
                    k_spec: Optional[int] = None,
                    done: Optional[jax.Array] = None,
                    temperature: float = 0.0,
                    key: Optional[jax.Array] = None,
                    k_lane: Optional[jax.Array] = None) -> BlockStep:
    """ONE speculative block-step against a live cache — the single owner of
    the draft -> verify -> commit logic.  Both ``speculative_generate`` (which
    loops it under ``jax.lax.while_loop``) and the continuous-batching serving
    engine (which interleaves it with per-slot admission/retirement) call this.

    pending: (B,) the last committed token per sequence.  done: (B,) bool —
    lanes marked done are masked out entirely (accept = 0, cache length and
    stateful-mixer states unchanged, pending passed through), which is how
    idle serving slots ride along in a fixed-size decode batch for free.

    k_lane: optional (B,) int32 per-lane speculation depth in [0, K].  The
    draft still runs K+1 feeds (static shapes, PRNG key schedule unchanged),
    but acceptance is masked so each lane commits at most ``k_lane + 1``
    tokens: positions at or beyond a lane's depth can never match (greedy)
    or be accepted (rejection sampling), and the correction/bonus token is
    drawn at position min(m, k_lane).  Rollback needs no new machinery — a
    short lane's extra eager writes are the same class of garbage as
    rejected full-depth drafts and roll back by length truncation.  With
    ``k_lane=None`` (or all lanes at K) the math is bit-identical to the
    fixed-depth path.

    temperature == 0: greedy drafting + longest-agreeing-prefix verification.
    temperature > 0: the drafter samples and the verifier runs Leviathan-style
    rejection sampling (lossless w.r.t. target-model sampling)."""
    cfg = model.cfg
    K = cfg.dvi.k_spec if k_spec is None else k_spec
    k, L = cfg.dvi.split_layer, cfg.num_layers
    B = pending.shape[0]
    sampling = temperature > 0.0
    key = key if key is not None else jax.random.PRNGKey(0)
    done = jnp.zeros((B,), bool) if done is None else done
    t0 = cache["lengths"]

    # done lanes must not advance draft state: a masked lane may be a lane
    # mid-chunked-prefill that will resume EXACTLY where it stopped, so its
    # stateful-mixer conv/state (which draft commits would otherwise evolve
    # on garbage pending tokens) and its draft lengths stay frozen.  Eager
    # attention writes still land but are rolled back by length masking.
    draft_accept = jnp.where(done, 0, 1).astype(jnp.int32)

    def draft_iter(carry, _):
        cache_c, pend, k_ = carry
        x = model.embed_block(params, pend[:, None], cache_c["lengths"])
        h_k, cache2, cands, _ = model.step(params, x, cache_c, 0, k)
        dlog = draft_logits(model, params, dvi_params, h_k[:, 0])
        if sampling:
            k_, sub = jax.random.split(k_)
            dprobs = jax.nn.softmax(dlog / temperature, axis=-1)
            d_tok = jax.random.categorical(sub, dlog / temperature).astype(jnp.int32)
        else:
            dprobs = jnp.zeros((B, 1), jnp.float32)     # unused placeholder
            d_tok = jnp.argmax(dlog, axis=-1).astype(jnp.int32)
        cache3 = tfm.commit_cache(cfg, cache2, cands, draft_accept)
        return (cache3, d_tok, k_), (h_k[:, 0], d_tok, dprobs, cands)

    (cache_d, _, key), (hk_s, d_s, dp_s, cand_stack) = jax.lax.scan(
        draft_iter, (cache, pending, key), None, length=K + 1)
    hk_blk = jnp.moveaxis(hk_s, 0, 1)                   # (B, K+1, d)
    d_blk = jnp.moveaxis(d_s, 0, 1)                     # (B, K+1)

    # ---- verify: one deep pass over the h_k block ----
    cache_v = dict(cache_d, lengths=t0)
    h_L_blk, cache_v2, deep_cands, _ = model.step(params, hk_blk, cache_v, k, L)
    vlogits = model.logits(params, h_L_blk)
    y_star = jnp.argmax(vlogits, axis=-1).astype(jnp.int32)       # (B, K+1)

    if sampling:
        key, sub = jax.random.split(key)
        vprobs = jax.nn.softmax(vlogits / temperature, axis=-1)
        dprobs = jnp.moveaxis(dp_s, 0, 1)               # (B, K+1, V)
        m, correction = rejection_commit(sub, d_blk, dprobs, vprobs,
                                         k_lane=k_lane)
    else:
        matches = (d_blk[:, :K] == y_star[:, :K])
        if k_lane is not None:
            matches = matches & (jnp.arange(K)[None, :] < k_lane[:, None])
        m = jnp.sum(jnp.cumprod(matches.astype(jnp.int32), axis=1), axis=1)
        correction = None
    accept = jnp.where(done, 0, m + 1)                  # (B,)

    all_cands = dict(_restack_cands(cand_stack), **deep_cands)
    cache_new = tfm.commit_cache(cfg, cache_v2, all_cands, accept)

    # ---- commit tokens ----
    ar = jnp.arange(K + 1)
    y_at_m = correction if sampling else \
        jnp.take_along_axis(y_star, m[:, None], axis=1)[:, 0]
    commit_vec = jnp.where(ar[None, :] < m[:, None], d_blk, y_at_m[:, None])
    new_pending = jnp.where(done, pending, y_at_m)
    return BlockStep(new_pending, commit_vec, accept, m, cache_new,
                     hk_blk, h_L_blk, d_blk, key)


def log_block_tuples(cfg, buf: dict, step: BlockStep, prev_pending: jax.Array,
                     done: jax.Array, k_spec: Optional[int] = None,
                     k_lane: Optional[jax.Array] = None) -> dict:
    """Append one block's accept/reject tuples to the replay buffer: drafted
    positions 1..K up to and including the first reject; lanes marked `done`
    (finished sequences, idle serving slots, padded lanes) are excluded.
    With per-lane depths (`k_lane`), positions beyond a lane's depth were
    never proposed and are excluded too — a depth-k lane logs at most k
    tuples, so a throttled lane also stops flooding the replay buffer."""
    K = cfg.dvi.k_spec if k_spec is None else k_spec
    if K == 0:
        return buf
    B = step.d_blk.shape[0]
    d = cfg.d_model
    i_idx = jnp.arange(1, K + 1)                        # (K,)
    lim = jnp.minimum(step.m + 1, K if k_lane is None else k_lane)
    valid = (~done)[:, None] & (i_idx[None, :] <= lim[:, None])
    reward = (i_idx[None, :] <= step.m[:, None]).astype(jnp.float32)
    prev = jnp.concatenate([prev_pending[:, None], step.d_blk[:, :K - 1]],
                           axis=1) if K > 1 else prev_pending[:, None]
    return buffer_mod.add_block(
        buf,
        step.hk_blk[:, :K].reshape(B * K, d),
        step.hL_blk[:, :K].reshape(B * K, d),
        step.d_blk[:, :K].reshape(B * K),
        reward.reshape(B * K),
        jnp.broadcast_to(i_idx[None], (B, K)).reshape(B * K),
        prev.reshape(B * K),
        valid.reshape(B * K))


def spec_superstep(model: Model, params: dict, dvi_params: dict,
                   pending: jax.Array, cache: dict, *, steps: int,
                   done: Optional[jax.Array] = None,
                   budget: Optional[jax.Array] = None,
                   eos_id: int = 1,
                   buf: Optional[dict] = None,
                   collect: bool = False,
                   k_spec: Optional[int] = None,
                   temperature: float = 0.0,
                   key: Optional[jax.Array] = None,
                   k_lane: Optional[jax.Array] = None,
                   depth_cfg=None,
                   accept_ema: Optional[jax.Array] = None,
                   k_cool: Optional[jax.Array] = None,
                   k_cap: Optional[jax.Array] = None) -> SuperstepResult:
    """Fused multi-block tick: run up to ``steps`` speculative blocks inside
    one ``jax.lax.while_loop`` so the serving engine syncs with the device
    once per superstep instead of once per block.

    Everything the per-block host loop did between dispatches happens
    in-graph: committed tokens are appended to a per-lane buffer with the
    exact sequential semantics of the host loop (stop at the lane's
    remaining ``budget``; stop just after the first EOS), lanes flip their
    ``done`` flag the block they exhaust budget or emit EOS (masking them
    out of every later block: accept = 0, cache untouched, no tuples), and
    per-lane block/commit/accept counters accumulate so host stats need only
    the compact summary.  The loop exits early once every lane is done.

    ``budget``: (B,) int32 REMAINING generation budget per lane (max_new
    minus tokens already emitted in earlier supersteps).  The committed
    stream across supersteps is bit-identical to per-block ticking — the
    only behavioural difference is that retirement/admission happen at
    superstep boundaries (a finished lane rides along masked until the
    host next harvests).

    Adaptive depth: ``k_lane`` (B,) gives each lane its own speculation
    depth <= K; with ``depth_cfg`` (a ``schedule.DepthConfig``) the depth
    controller also runs IN-GRAPH after every block — the acceptance EMA
    (``accept_ema``) and cooldown (``k_cool``) ride the while-loop carry
    and the updated (k, ema, cool) come back in the result, so adapting
    depth per block costs zero extra host syncs.  ``k_cap`` (B,) is a hard
    per-lane ceiling the controller cannot raise k beyond — the serving
    engine passes the depth it provisioned KV pages for, decoupling pool
    soundness from controller behaviour.  Depth changes take effect at the
    NEXT block (boundaries only — the adaptive-depth contract).  All of
    this is inert by default: with ``k_lane=None`` and ``depth_cfg=None``
    the block math is bit-identical to the fixed-depth path."""
    cfg = model.cfg
    K = cfg.dvi.k_spec if k_spec is None else k_spec
    B = pending.shape[0]
    key = key if key is not None else jax.random.PRNGKey(0)
    done = jnp.zeros((B,), bool) if done is None else done
    budget = (jnp.full((B,), jnp.iinfo(jnp.int32).max // 2, jnp.int32)
              if budget is None else budget.astype(jnp.int32))
    if collect and buf is None:
        buf = buffer_mod.init_buffer(cfg)
    ragged = k_lane is not None
    k0 = (jnp.full((B,), K, jnp.int32) if k_lane is None
          else k_lane.astype(jnp.int32))
    ema0 = (jnp.zeros((B,), jnp.float32) if accept_ema is None
            else accept_ema.astype(jnp.float32))
    cool0 = (jnp.zeros((B,), jnp.int32) if k_cool is None
             else k_cool.astype(jnp.int32))
    khi = None if k_cap is None else jnp.minimum(k_cap.astype(jnp.int32), K)
    cap = steps * (K + 1)
    ar = jnp.arange(K + 1)
    lane = jnp.arange(B)
    zeros = jnp.zeros((B,), jnp.int32)

    def body(carry):
        (i, pending, done, gen_buf, gen_count, blocks, committed, accepted,
         drafted, k, ema, cool, a_hist, d_hist, cache, buf, key) = carry
        live = (~done).astype(jnp.int32)
        blk = spec_block_step(model, params, dvi_params, pending, cache,
                              k_spec=K, done=done, temperature=temperature,
                              key=key, k_lane=k if ragged else None)
        # sequential commit semantics, vectorized: candidate positions are
        # the accepted prefix that still fits the lane budget; an EOS among
        # them is written and stops everything after it
        can = ((ar[None, :] < blk.accept[:, None])
               & (gen_count[:, None] + ar[None, :] < budget[:, None]))
        hit_eos = can & (blk.commit_vec == eos_id)
        eos_before = jnp.cumsum(hit_eos.astype(jnp.int32), axis=1) \
            - hit_eos.astype(jnp.int32)
        written = can & (eos_before == 0)
        dest = jnp.where(written,
                         lane[:, None] * cap + gen_count[:, None] + ar[None, :],
                         B * cap)                           # OOB -> dropped
        gen_buf = gen_buf.reshape(-1).at[dest.reshape(-1)].set(
            blk.commit_vec.reshape(-1), mode="drop").reshape(B, cap)
        new_count = gen_count + written.sum(axis=1, dtype=jnp.int32)
        new_done = done | jnp.any(hit_eos, axis=1) | (new_count >= budget)
        if collect:
            buf = log_block_tuples(cfg, buf, blk, pending, done, k_spec=K,
                                   k_lane=k if ragged else None)
        drafted = drafted + k * live     # depth the block actually ran at
        # telemetry histograms, in-graph and UNCONDITIONAL (telemetry on/off
        # shares one compiled graph): per live block, bucket the verifier's
        # accepted-draft count m and the depth k the block ran at.  Rides
        # the superstep's existing host sync — zero extra device round-trips
        a_hist = a_hist.at[blk.m].add(live, mode="drop")
        d_hist = d_hist.at[k].add(live, mode="drop")
        if depth_cfg is not None:
            # controller sees THIS block's outcome (depth k, accepted m) and
            # adjusts for the next block; masked lanes keep frozen state
            k, ema, cool = schedule_mod.depth_update(
                depth_cfg, k, ema, cool, blk.m, ~done, k_hi=khi)
        return (i + 1, blk.pending, new_done, gen_buf, new_count,
                blocks + live, committed + blk.accept,
                accepted + blk.m * live, drafted,
                k, ema, cool, a_hist, d_hist, blk.cache, buf, blk.key)

    def cond(carry):
        return (carry[0] < steps) & ~jnp.all(carry[2])

    hist0 = jnp.zeros((K + 1,), jnp.int32)
    carry = (jnp.int32(0), pending, done, jnp.zeros((B, cap), jnp.int32),
             zeros, zeros, zeros, zeros, zeros, k0, ema0, cool0,
             hist0, hist0, cache, buf, key)
    (_, pending, done, gen_buf, gen_count, blocks, committed, accepted,
     drafted, k_out, ema_out, cool_out, a_hist, d_hist, cache, buf, key) = \
        jax.lax.while_loop(cond, body, carry)
    return SuperstepResult(pending, done, gen_buf, gen_count, blocks,
                           committed, accepted, drafted, k_out, ema_out,
                           cool_out, a_hist, d_hist, cache, buf, key)


def speculative_generate(model: Model, params: dict, dvi_params: dict,
                         prompts: jax.Array, max_new: int,
                         k_spec: Optional[int] = None,
                         cache_len: Optional[int] = None,
                         eos_id: int = 1,
                         collect: bool = False,
                         buf: Optional[dict] = None,
                         aux_inputs: Optional[dict] = None,
                         temperature: float = 0.0,
                         key: Optional[jax.Array] = None,
                         live_mask: Optional[jax.Array] = None) -> GenResult:
    """Batched lossless speculative generation with optional tuple logging.

    prompts: (B, Tp) with Tp >= 2, all sequences the same length (serving
    buckets/pads upstream — required for exact stateful-mixer prefill).

    temperature == 0 (paper setting): greedy drafting + longest-prefix
    verification.  temperature > 0 (beyond-paper): the drafter *samples*
    and the verifier runs Leviathan-style rejection sampling — the emitted
    stream is distributed exactly as target-model sampling.

    live_mask: (B,) bool — lanes marked False (e.g. batch-padding duplicates
    in the sync serving path) generate nothing, log no tuples, and count in
    no statistics."""
    cfg = model.cfg
    K = cfg.dvi.k_spec if k_spec is None else k_spec
    B, Tp = prompts.shape
    key = key if key is not None else jax.random.PRNGKey(0)
    assert Tp >= 2, "need at least 2 prompt tokens (one prefill + one pending)"
    total = Tp + max_new + K + 2
    cache_cap = cache_len or (total + tfm.RING_SLACK)

    # ---- prefill all but the last prompt token; it becomes `pending` ----
    _, cache, _ = model.prefill(params, prompts[:, :Tp - 1], aux_inputs,
                                max_len=cache_cap)
    pending = prompts[:, Tp - 1]
    out = jnp.zeros((B, total), jnp.int32).at[:, :Tp].set(prompts)
    out_len = jnp.full((B,), Tp, jnp.int32)
    done = jnp.zeros((B,), bool) if live_mask is None else ~live_mask
    if collect and buf is None:
        buf = buffer_mod.init_buffer(cfg)
    stats = {k_: jnp.int32(0) for k_ in
             ("blocks", "committed", "accepted_drafts", "drafted")}

    def body(carry):
        out, out_len, pending, done, cache, buf, stats, key = carry
        blk = spec_block_step(model, params, dvi_params, pending, cache,
                              k_spec=K, done=done, temperature=temperature,
                              key=key)
        out = jax.vmap(lambda o, cv, s: jax.lax.dynamic_update_slice(o, cv, (s,)))(
            out, blk.commit_vec, out_len)
        ar = jnp.arange(K + 1)
        emitted_eos = jnp.any((ar[None, :] < blk.accept[:, None])
                              & (blk.commit_vec == eos_id), axis=1)
        out_len = out_len + blk.accept
        new_done = done | emitted_eos | (out_len >= Tp + max_new)

        if collect:
            buf = log_block_tuples(cfg, buf, blk, pending, done, k_spec=K)

        live = (~done).astype(jnp.int32)
        stats2 = {
            "blocks": stats["blocks"] + live.sum(),
            "committed": stats["committed"] + blk.accept.sum(),
            "accepted_drafts": stats["accepted_drafts"] + (blk.m * live).sum(),
            "drafted": stats["drafted"] + K * live.sum(),
        }
        return (out, out_len, blk.pending, new_done, blk.cache, buf, stats2,
                blk.key)

    def cond(carry):
        done = carry[3]
        return ~jnp.all(done)

    carry = (out, out_len, pending, done, cache, buf, stats, key)
    out, out_len, pending, done, cache, buf, stats, key = jax.lax.while_loop(
        cond, body, carry)
    return GenResult(out, out_len, stats["blocks"], stats["committed"],
                     stats["accepted_drafts"], stats["drafted"], buf)


def ar_generate(model: Model, params: dict, prompts, max_new, **kw):
    """Plain greedy autoregressive decoding of the target path (K = 0)."""
    dvi_dummy = {"A": jnp.zeros((model.cfg.d_model, 1), jnp.float32),
                 "B": jnp.zeros((1, model.cfg.vocab_size), jnp.float32)}
    return speculative_generate(model, params, dvi_dummy, prompts, max_new,
                                k_spec=0, collect=False, **kw)


def serve_step(model: Model, params: dict, dvi_params: dict, pending,
               cache, k_spec: Optional[int] = None):
    """ONE greedy speculative step against an existing cache — the unit the
    decode dry-run shapes lower (decode_32k / long_500k).  Thin compatibility
    wrapper over ``spec_block_step`` (the single draft/verify/commit owner).
    Returns (new_pending, commit_vec, accept, new_cache)."""
    blk = spec_block_step(model, params, dvi_params, pending, cache,
                          k_spec=k_spec)
    return blk.pending, blk.commit_vec, blk.accept, blk.cache
