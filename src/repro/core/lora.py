"""LoRA-parameterized draft head (paper §3.1).

    p_theta(. | h_k) = softmax((W_S + gamma_s * A_s B_s) h_k)

W_S is the *frozen* base projection — we tie it to the verifier's LM head
(so at init, with B_s = 0, the drafter is exactly "the verifier head read at
layer k": the natural self-speculation bootstrap, and it means we never
materialize a second (d, V) matrix).  Only (A_s, B_s) train.

The draft path reuses the backbone's frozen final RMSNorm on h_k before the
projection (the verifier head sees normed h_L; giving the drafter the same
frozen normalization keeps the two heads in one logit space, which is what
makes the KL warmup well-conditioned).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm
from repro.models.model import Model


def init_draft_params(key, cfg: ModelConfig) -> dict:
    r = cfg.dvi.lora_rank
    d, V = cfg.d_model, cfg.vocab_size
    ka, _ = jax.random.split(key)
    return {
        "A": (jax.random.normal(ka, (d, r), jnp.float32) / jnp.sqrt(d)
              ).astype(jnp.float32),
        "B": jnp.zeros((r, V), jnp.float32),
    }


def draft_logits(model: Model, params: dict, dvi_params: dict,
                 h_k: jax.Array) -> jax.Array:
    """h_k (..., d) -> logits (..., V) in float32."""
    cfg = model.cfg
    gamma = cfg.dvi.lora_alpha / cfg.dvi.lora_rank
    hn = rms_norm(h_k, params["final_norm"], cfg.norm_eps)
    base = (hn @ model.head_matrix(params)).astype(jnp.float32)
    lora = (hn.astype(jnp.float32) @ dvi_params["A"]) @ dvi_params["B"]
    return base + gamma * lora


def num_trainable(dvi_params) -> int:
    return sum(p.size for p in jax.tree.leaves(dvi_params))
