"""Comparison baselines from the paper's Table 2 / Table 3.

* ``ar_generate``           — vanilla greedy AR (the 1.00x reference; in spec.py).
* ``two_model_generate``    — classic SD (Leviathan'23 / SpS): a separate
                              small drafter LM proposes K tokens, the target
                              verifies in one pass.
* static self-speculation   — Zhang'23-style: DVI geometry with an
                              *untrained* draft head (LoRA B=0 at init means
                              the drafter is exactly the frozen verifier head
                              read at layer k) — i.e. DVI at step 0.
* KL-only / PG-only / CE-only — the paper's §4.3 single-term ablations:
                              ``online_loop(..., mode='kl'|'pg'|'ce')``.
* ``MedusaLite``            — Medusa-style time-independent extra heads on
                              h_L, sequential (non-tree) verification, heads
                              trained offline with teacher-forced CE.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.spec import GenResult
from repro.models import transformer as tfm
from repro.models.layers import dense_init, rms_norm, split_keys
from repro.models.model import Model
from repro.optim import adamw_init, adamw_update


# ---------------------------------------------------------------------------
# Two-model speculative decoding (SpS)
# ---------------------------------------------------------------------------

def two_model_generate(target: Model, t_params: dict, draft: Model,
                       d_params: dict, prompts: jax.Array, max_new: int,
                       k_spec: int = 4, eos_id: int = 1,
                       cache_len: Optional[int] = None) -> GenResult:
    """Classic lossless SD with a separate drafter LM (greedy).

    Both models run their own KV cache — exactly the system overhead DVI's
    single-model geometry removes (paper §1)."""
    K = k_spec
    B, Tp = prompts.shape
    total = Tp + max_new + K + 2
    cap = cache_len or (total + tfm.RING_SLACK)

    _, t_cache, _ = target.prefill(t_params, prompts[:, :Tp - 1], max_len=cap)
    _, d_cache, _ = draft.prefill(d_params, prompts[:, :Tp - 1], max_len=cap)
    pending = prompts[:, Tp - 1]
    out = jnp.zeros((B, total), jnp.int32).at[:, :Tp].set(prompts)
    out_len = jnp.full((B,), Tp, jnp.int32)
    done = jnp.zeros((B,), bool)
    stats = {k: jnp.int32(0) for k in ("blocks", "committed",
                                       "accepted_drafts", "drafted")}

    def draft_iter(carry, _):
        dc, pend = carry
        x = draft.embed_block(d_params, pend[:, None], dc["lengths"])
        h, dc2, cands, _ = draft.step(d_params, x, dc)
        logits = draft.logits(d_params, h[:, 0])
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        dc3 = tfm.commit_cache(draft.cfg, dc2, cands, jnp.ones((B,), jnp.int32))
        return (dc3, tok), tok

    def body(carry):
        out, out_len, pending, done, t_cache, d_cache, stats = carry
        t0t = t_cache["lengths"]
        t0d = d_cache["lengths"]
        (d_cache_d, _), d_s = jax.lax.scan(draft_iter, (d_cache, pending),
                                           None, length=K)
        d_blk = jnp.moveaxis(d_s, 0, 1)                      # (B, K)
        # target verifies tokens [pending, d_1..d_K] in one pass
        tok_blk = jnp.concatenate([pending[:, None], d_blk], axis=1)  # (B,K+1)
        x = target.embed_block(t_params, tok_blk, t0t)
        h, t_cache2, t_cands, _ = target.step(t_params, x, t_cache)
        y_star = jnp.argmax(target.logits(t_params, h), axis=-1).astype(jnp.int32)
        matches = (d_blk == y_star[:, :K])
        m = jnp.sum(jnp.cumprod(matches.astype(jnp.int32), axis=1), axis=1)
        accept = jnp.where(done, 0, m + 1)
        t_cache3 = tfm.commit_cache(target.cfg, t_cache2, t_cands, accept)

        # drafter cache consumed K feeds from t0d; roll back to t0d + accept
        # (accept <= K+1; the (K+1)-th token was never fed to the drafter, so
        # clamp to K and let the next block re-feed)
        d_accept = jnp.where(done, 0, jnp.minimum(accept, K))
        d_cands = jax.tree.map(lambda a: a, {})  # attn-only drafters: none
        d_cache3 = dict(d_cache_d, lengths=t0d + d_accept)

        ar = jnp.arange(K + 1)
        y_at_m = jnp.take_along_axis(y_star, m[:, None], axis=1)[:, 0]
        commit_vec = jnp.where(ar[None, :] < m[:, None],
                               jnp.pad(d_blk, ((0, 0), (0, 1))), y_at_m[:, None])
        out = jax.vmap(lambda o, cv, s: jax.lax.dynamic_update_slice(o, cv, (s,)))(
            out, commit_vec, out_len)
        emitted_eos = jnp.any((ar[None, :] < accept[:, None])
                              & (commit_vec == eos_id), axis=1)
        out_len = out_len + accept
        new_done = done | emitted_eos | (out_len >= Tp + max_new)
        new_pending = jnp.where(done, pending, y_at_m)
        live = (~done).astype(jnp.int32)
        stats2 = {"blocks": stats["blocks"] + live.sum(),
                  "committed": stats["committed"] + accept.sum(),
                  "accepted_drafts": stats["accepted_drafts"] + (m * live).sum(),
                  "drafted": stats["drafted"] + K * live.sum()}
        return (out, out_len, new_pending, new_done, t_cache3, d_cache3, stats2)

    carry = (out, out_len, pending, done, t_cache, d_cache, stats)
    out, out_len, *_, stats = jax.lax.while_loop(lambda c: ~jnp.all(c[3]),
                                                 body, carry)
    return GenResult(out, out_len, stats["blocks"], stats["committed"],
                     stats["accepted_drafts"], stats["drafted"], None)


# ---------------------------------------------------------------------------
# Medusa-lite: extra time-independent heads on h_L, sequential verification
# ---------------------------------------------------------------------------

def init_medusa_heads(key, model: Model, num_heads: int = 3) -> dict:
    cfg = model.cfg
    ks = split_keys(key, num_heads)
    # residual-block head per Medusa: W2 silu(W1 h) + h  -> lm_head
    return {"w1": jnp.stack([dense_init(k, (cfg.d_model, cfg.d_model),
                                        jnp.float32, scale=0.01) for k in ks]),
            }


def medusa_head_logits(model: Model, params: dict, heads: dict, h: jax.Array):
    """h (..., d) -> (num_heads, ..., V)."""
    def one(w1):
        z = h + jax.nn.silu(h.astype(jnp.float32) @ w1).astype(h.dtype)
        return model.logits(params, z)
    return jax.vmap(one)(heads["w1"])


def train_medusa_heads(model: Model, params: dict, heads: dict, data_stream,
                       lr: float = 1e-3, log_every: int = 0):
    """Offline teacher-forced CE: head i predicts token t+2+i from h_L(t)."""
    opt = adamw_init(heads)
    n_heads = heads["w1"].shape[0]

    @jax.jit
    def step(heads, opt, tokens):
        def loss_fn(hd):
            x = model.embed(params, tokens)
            h, _, _ = model.hidden(params, x)
            losses = []
            for i in range(n_heads):
                off = 2 + i
                hh = h[:, :-off]
                logits = medusa_head_logits(model, params,
                                            {"w1": hd["w1"][i:i+1]}, hh)[0]
                logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
                tgt = tokens[:, off:]
                losses.append(-jnp.take_along_axis(
                    logp, tgt[..., None], axis=-1).mean())
            return sum(losses) / n_heads
        loss, grads = jax.value_and_grad(loss_fn)(heads)
        heads, opt, _ = adamw_update(heads, grads, opt, lr)
        return heads, opt, loss

    for i, tokens in enumerate(data_stream):
        heads, opt, loss = step(heads, opt, tokens)
        if log_every and (i + 1) % log_every == 0:
            print(f"[medusa] step {i+1}: loss={float(loss):.4f}")
    return heads


def medusa_generate(model: Model, params: dict, heads: dict, prompts,
                    max_new: int, eos_id: int = 1,
                    cache_len: Optional[int] = None) -> GenResult:
    """Sequential (non-tree) Medusa decoding: block = [lm(h), head_i(h)...]."""
    n_heads = heads["w1"].shape[0]
    K = 1 + n_heads
    B, Tp = prompts.shape
    total = Tp + max_new + K + 2
    cap = cache_len or (total + tfm.RING_SLACK)
    h_last, cache, _ = model.prefill(params, prompts[:, :Tp - 1], max_len=cap)
    pending = prompts[:, Tp - 1]
    out = jnp.zeros((B, total), jnp.int32).at[:, :Tp].set(prompts)
    out_len = jnp.full((B,), Tp, jnp.int32)
    done = jnp.zeros((B,), bool)
    stats = {k: jnp.int32(0) for k in ("blocks", "committed",
                                       "accepted_drafts", "drafted")}

    def body(carry):
        out, out_len, pending, done, cache, stats = carry
        t0 = cache["lengths"]
        # 1 target step on pending -> h; lm + medusa heads propose K tokens
        x = model.embed_block(params, pending[:, None], t0)
        h, cache1, cands1, _ = model.step(params, x, cache)
        cache1 = tfm.commit_cache(model.cfg, cache1, cands1,
                                  jnp.ones((B,), jnp.int32))
        h0 = h[:, 0]
        lm_tok = jnp.argmax(model.logits(params, h0), -1).astype(jnp.int32)
        head_logits = medusa_head_logits(model, params, heads, h0)
        head_toks = jnp.argmax(head_logits, -1).astype(jnp.int32)   # (nh, B)
        d_blk = jnp.concatenate([lm_tok[:, None], head_toks.T], axis=1)  # (B,K)
        # verify d_blk through the target in one pass
        xb = model.embed_block(params, d_blk, cache1["lengths"])
        hb, cache2, cands2, _ = model.step(params, xb, cache1)
        y_star = jnp.argmax(model.logits(params, hb), -1).astype(jnp.int32)
        # d_blk[0] == lm_tok is by construction the target's token (always
        # accepted); matches for proposals 2..K
        matches = (d_blk[:, 1:] == y_star[:, :K - 1])
        m = 1 + jnp.sum(jnp.cumprod(matches.astype(jnp.int32), axis=1), axis=1)
        accept = jnp.where(done, 0, m + 1)
        # cache1 already advanced 1 (the pending feed); block feeds advance
        # accept-1 more of the K feeds
        blk_accept = jnp.where(done, 0, jnp.minimum(m, K))
        cache3 = tfm.commit_cache(model.cfg, cache2, cands2, blk_accept)
        cache3 = dict(cache3, lengths=jnp.where(done, t0, t0 + 1 + blk_accept))

        ar = jnp.arange(K + 1)
        y_at = jnp.take_along_axis(y_star, jnp.maximum(m - 1, 0)[:, None],
                                   axis=1)[:, 0]
        commit_vec = jnp.where(ar[None, :] < m[:, None],
                               jnp.pad(d_blk, ((0, 0), (0, 1))), y_at[:, None])
        out = jax.vmap(lambda o, cv, s: jax.lax.dynamic_update_slice(o, cv, (s,)))(
            out, commit_vec, out_len)
        emitted_eos = jnp.any((ar[None, :] < accept[:, None])
                              & (commit_vec == eos_id), axis=1)
        out_len = out_len + accept
        new_done = done | emitted_eos | (out_len >= Tp + max_new)
        new_pending = jnp.where(done, pending, y_at)
        live = (~done).astype(jnp.int32)
        stats2 = {"blocks": stats["blocks"] + live.sum(),
                  "committed": stats["committed"] + accept.sum(),
                  "accepted_drafts": stats["accepted_drafts"] + ((m - 1) * live).sum(),
                  "drafted": stats["drafted"] + (K - 1) * live.sum()}
        return (out, out_len, new_pending, new_done, cache3, stats2)

    carry = (out, out_len, pending, done, cache, stats)
    out, out_len, _, _, _, stats = jax.lax.while_loop(lambda c: ~jnp.all(c[3]),
                                                      body, carry)
    return GenResult(out, out_len, stats["blocks"], stats["committed"],
                     stats["accepted_drafts"], stats["drafted"], None)
