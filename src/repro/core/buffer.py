"""Online replay ring buffer (paper §3.3).

Each logged tuple is one drafted position up to and including the first
reject:  (h_k, h_L, action, reward, block_pos, prev_id).  We store h_L
instead of the verifier logits — with a frozen head they carry identical
information and d_model << vocab makes the buffer ~V/d smaller (documented
deviation in DESIGN.md §3).

Fixed-shape device arrays so logging happens *inside* the jitted generation
loop; compaction uses a prefix-sum scatter with mode='drop' for invalid
(counterfactual / done-sequence) rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def init_buffer(cfg: ModelConfig, slots: int = 0, dtype=jnp.float32) -> dict:
    S = slots or cfg.dvi.buffer_slots
    d = cfg.d_model
    return {
        "h_k": jnp.zeros((S, d), dtype),
        "h_L": jnp.zeros((S, d), dtype),
        "action": jnp.zeros((S,), jnp.int32),
        "reward": jnp.zeros((S,), jnp.float32),
        "pos": jnp.zeros((S,), jnp.int32),       # i: 1-indexed block position
        "prev": jnp.zeros((S,), jnp.int32),
        "age": jnp.zeros((S,), jnp.int32),       # write-generation (freshness)
        "ptr": jnp.int32(0),
        "count": jnp.int32(0),
        "gen": jnp.int32(0),
    }


def add_block(buf: dict, h_k, h_L, action, reward, pos, prev, valid) -> dict:
    """Append rows where valid.  All inputs flat (N, ...) / (N,)."""
    S = buf["h_k"].shape[0]
    N = valid.shape[0]
    vi = valid.astype(jnp.int32)
    offs = jnp.cumsum(vi) - vi                      # 0-based rank among valid
    total = vi.sum()
    dest = (buf["ptr"] + offs) % S
    dest = jnp.where(valid, dest, S)                # S -> dropped

    new = dict(buf)
    new["h_k"] = buf["h_k"].at[dest].set(h_k.astype(buf["h_k"].dtype), mode="drop")
    new["h_L"] = buf["h_L"].at[dest].set(h_L.astype(buf["h_L"].dtype), mode="drop")
    new["action"] = buf["action"].at[dest].set(action.astype(jnp.int32), mode="drop")
    new["reward"] = buf["reward"].at[dest].set(reward.astype(jnp.float32), mode="drop")
    new["pos"] = buf["pos"].at[dest].set(pos.astype(jnp.int32), mode="drop")
    new["prev"] = buf["prev"].at[dest].set(prev.astype(jnp.int32), mode="drop")
    new["age"] = buf["age"].at[dest].set(buf["gen"], mode="drop")
    new["ptr"] = (buf["ptr"] + total) % S
    new["count"] = jnp.minimum(buf["count"] + total, S)
    new["gen"] = buf["gen"] + 1
    return new


def sample(buf: dict, key, batch_size: int) -> dict:
    """Uniform sample (with replacement) of `batch_size` logged tuples.
    Rows are masked invalid when the buffer holds fewer than batch_size."""
    S = buf["h_k"].shape[0]
    cnt = jnp.maximum(buf["count"], 1)
    idx = jax.random.randint(key, (batch_size,), 0, cnt)
    # newest-first ordering not required for uniform sampling; map rank->slot
    slot = (buf["ptr"] - 1 - idx) % S
    batch = {k: buf[k][slot] for k in
             ("h_k", "h_L", "action", "reward", "pos", "prev", "age")}
    batch["mask"] = (idx < buf["count"]).astype(jnp.float32)
    return batch


def fresh_batch(buf: dict, batch_size: int) -> dict:
    """The most recently written tuples (on-policy slice, paper's 'fresh')."""
    S = buf["h_k"].shape[0]
    offs = jnp.arange(batch_size)
    slot = (buf["ptr"] - 1 - offs) % S
    batch = {k: buf[k][slot] for k in
             ("h_k", "h_L", "action", "reward", "pos", "prev", "age")}
    fresh = buf["age"][slot] == buf["gen"] - 1
    batch["mask"] = (fresh & (offs < buf["count"])).astype(jnp.float32)
    return batch
