"""Three-term roofline model (TPU v5e) from dry-run artifacts + analytic
byte/FLOP models.

    compute term    = FLOPs_per_chip / peak_FLOPs            (197 TFLOP/s bf16)
    memory term     = HBM_bytes_per_chip / HBM_bw            (819 GB/s)
    collective term = wire_bytes_per_chip / ICI_bw           (~50 GB/s/link)

FLOPs come from the trip-count-weighted HLO analysis (repro.launch.
hlo_analysis — XLA's cost_analysis counts scan bodies once and is recorded
only for reference).  HBM bytes are analytic (weights/caches/activations per
the execution plan) because fused-loop byte counts are not recoverable from
HLO text; the model below is documented per term.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import InputShape, ModelConfig

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (single-link conservative)
HBM_PER_CHIP = 16 * 2**30    # v5e


def _cache_bytes(cfg: ModelConfig, S: int, B: int) -> int:
    """Decode-cache bytes (global) for capacity S, batch B."""
    by = 0
    from repro.models.transformer import RING_SLACK, model_segments
    for seg in model_segments(cfg):
        n = seg.n
        if seg.kind == "ssm":
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            H = d_in // s.head_dim
            conv_dim = d_in + 2 * s.ngroups * s.d_state
            by += n * B * ((s.d_conv - 1) * conv_dim * 2
                           + H * s.head_dim * s.d_state * 4)
        elif seg.kind == "rglru":
            w = cfg.rglru.lru_width or cfg.d_model
            by += n * B * ((cfg.rglru.d_conv - 1) * w * 2 + w * 4)
        elif cfg.mla is not None:
            by += n * B * S * (cfg.mla.kv_lora_rank
                               + cfg.mla.qk_rope_head_dim) * 2
        else:
            C = (cfg.rglru.local_window if cfg.rglru is not None else
                 cfg.sliding_window) + RING_SLACK if seg.kind == "local" else S
            by += n * B * C * 2 * cfg.num_kv_heads * cfg.resolved_head_dim * 2
        if seg.cross:
            by += n * B * cfg.encoder.num_frames * 2 * cfg.num_heads \
                * cfg.resolved_head_dim * 2
    return by


def analytic_hbm_bytes(cfg: ModelConfig, shape: InputShape,
                       n_chips: int) -> float:
    """Per-chip HBM traffic estimate for one step of the shape's workload."""
    B, S = shape.global_batch, shape.seq_len
    d, L = cfg.d_model, cfg.num_layers
    K = cfg.dvi.k_spec
    wbytes = cfg.param_count() * 2                      # bf16 resident
    if shape.kind == "decode":
        # weight-stationary: read every (active) weight shard once + the
        # whole cache once per verify step (+ drafter reads shallow K+1x)
        k = cfg.dvi.split_layer
        shallow_frac = k / L
        w_read = cfg.active_param_count() * 2 * (1 + shallow_frac * K)
        c_read = _cache_bytes(cfg, S, B) * (K + 2) / (K + 2)  # once + writes ~eps
        act = B * (K + 1) * d * L * 4 * 2
        return (w_read + c_read + act) / n_chips
    tokens = B * S
    # weights once; activations ~6 r/w of (tokens, d) per layer; flash k/v
    # re-read nq times per layer; cache write once (prefill)
    act = 6 * L * tokens * d * 2
    nq = max(S // 256, 1)
    kv_bytes = L * tokens * 2 * cfg.num_kv_heads * cfg.resolved_head_dim * 2
    flash_reread = min(nq, 32) * kv_bytes * 0.1         # chunked re-reads, est.
    total = wbytes + act + kv_bytes + flash_reread
    if shape.kind == "train":
        total += wbytes                                  # (LoRA-only bwd reads)
    return total / n_chips


def model_flops(cfg: ModelConfig, shape: InputShape) -> dict:
    """Ideal 'useful' FLOPs: 2*N_active*tokens forward (+attention)."""
    B, S = shape.global_batch, shape.seq_len
    N = cfg.active_param_count()
    hd = cfg.resolved_head_dim
    if shape.kind == "decode":
        K = cfg.dvi.k_spec
        toks = B * (K + 1)
        k_frac = cfg.dvi.split_layer / cfg.num_layers
        fwd = 2 * N * toks * (1 + k_frac * 1.0)          # drafter re-walks shallow
        attn = 2 * 2 * toks * S * cfg.num_heads * hd * (1 - k_frac)
        return {"forward": fwd + attn, "six_nd": 6 * N * toks}
    toks = B * S
    causal = 0.5
    attn_ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S
    attn = (0 if cfg.arch_type == "ssm" else
            4 * cfg.num_layers * toks * attn_ctx * cfg.num_heads * hd * causal)
    fwd = 2 * N * toks + attn
    return {"forward": fwd, "six_nd": 6 * N * toks}


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_from_record(rec: dict, cfg: ModelConfig,
                         shape: InputShape) -> dict:
    n_chips = rec.get("n_devices", 256)
    flops_dev = rec["cost"]["dot_flops_per_device"]
    wire_dev = rec["collectives"]["total"]["wire_bytes"]
    hbm_dev = analytic_hbm_bytes(cfg, shape, n_chips)
    r = Roofline(compute_s=flops_dev / PEAK_FLOPS,
                 memory_s=hbm_dev / HBM_BW,
                 collective_s=wire_dev / ICI_BW)
    mf = model_flops(cfg, shape)
    useful_ratio = mf["forward"] / max(flops_dev * n_chips, 1.0)
    return {
        "compute_s": r.compute_s,
        "memory_s": r.memory_s,
        "collective_s": r.collective_s,
        "dominant": r.dominant,
        "bound_s": r.bound_s,
        "hbm_bytes_per_chip": hbm_dev,
        "model_flops_fwd": mf["forward"],
        "model_flops_6nd": mf["six_nd"],
        "useful_flops_ratio": useful_ratio,
        "peak_mem_gib": rec["memory"]["peak_bytes"] / 2**30,
        "fits_hbm": rec["memory"]["peak_bytes"] < HBM_PER_CHIP,
    }


def suggestion(rl: dict) -> str:
    if rl["dominant"] == "collective":
        return ("reduce all-gather/all-reduce volume: shard attention heads "
                "on 'model', overlap FSDP gathers with compute, or move the "
                "KL/logit reductions to reduce-scatter")
    if rl["dominant"] == "memory":
        return ("cut HBM traffic: fuse verify head (verify_argmax kernel "
                "avoids the (T,V) logits round-trip), quantize KV cache, or "
                "increase arithmetic intensity with larger decode batch")
    if rl["useful_flops_ratio"] < 0.5:
        return ("compiled compute exceeds useful model FLOPs — remove "
                "redundant (replicated-head) attention compute or remat "
                "recompute; then raise MXU utilization via 128-aligned tiles")
    return "near compute roof: tune block shapes / MXU alignment"
