"""Shared benchmark scaffolding: a small pretrained backbone + timing."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticTasks, TASK_CATEGORIES
from repro.models.model import build_model
from repro.training import pretrain

EOS = 1


def bench_backbone(arch="vicuna-7b", pretrain_steps=250, seed=0):
    """Tiny fp32 backbone pretrained on the synthetic 6-task mixture so the
    verifier distribution is peaked (as a real LM's is)."""
    cfg = get_config(arch, tiny=True).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    tasks = SyntheticTasks(cfg.vocab_size, seed=seed)
    params, _ = pretrain(model, params,
                         tasks.stream(TASK_CATEGORIES, pretrain_steps, 16, 32,
                                      seed=seed + 9), lr=2e-3)
    return cfg, model, params, tasks


def timed(fn, *args, warmup=1, iters=3):
    """Returns (median_seconds, result)."""
    res = None
    for _ in range(warmup):
        res = fn(*args)
        jax.block_until_ready(jax.tree.leaves(res)[0])
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        res = fn(*args)
        jax.block_until_ready(jax.tree.leaves(res)[0])
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), res


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
