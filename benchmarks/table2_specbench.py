"""Paper Table 2: Spec-Bench-style comparison — MAT + wall-time speedup per
task category, DVI vs AR / two-model SD / static self-spec / Medusa-lite.

Real wall-time on CPU with a tiny pretrained backbone over the synthetic
6-category suite (mirrors Spec-Bench's MT-Bench/Translation/Summarization/
QA/Math/RAG split).  DVI is trained online on a ShareGPT-like mixed stream
first (one pass, paper protocol), then evaluated frozen.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_backbone, emit, timed
from repro.configs.base import DVIConfig
from repro.core import baselines, online, spec
from repro.data import TASK_CATEGORIES
from repro.models.model import build_model
from repro.training import pretrain

EVAL_PROMPTS = 8
PROMPT_LEN = 16
MAX_NEW = 48


def _gen_time(fn, prompts):
    t, res = timed(fn, prompts, warmup=1, iters=3)
    toks = float(res.committed)
    mat = toks / max(float(res.blocks), 1.0)
    return t, mat, toks


def main(train_batches: int = 150):
    cfg, model, params, tasks = bench_backbone(pretrain_steps=250)

    # --- online DVI training: one pass over a mixed prompt stream ---
    state = online.init_trainer(model, jax.random.PRNGKey(7))
    stream = tasks.stream(TASK_CATEGORIES, train_batches, 8, PROMPT_LEN,
                          seed=11)
    state, hist = online.online_loop(model, params, stream, state,
                                     max_new=24, mode="full", lr=3e-3)

    # --- separate-drafter baseline (2-layer) trained on the same data ---
    dcfg = cfg.replace(name="drafter", num_layers=2,
                       dvi=DVIConfig(split_layer=1))
    draft = build_model(dcfg)
    d_params = draft.init(jax.random.PRNGKey(3))
    d_params, _ = pretrain(draft, d_params,
                           tasks.stream(TASK_CATEGORIES, 150, 16, 32, seed=9),
                           lr=2e-3)

    # --- medusa-lite heads trained offline on the same stream ---
    heads = baselines.init_medusa_heads(jax.random.PRNGKey(9), model, 3)
    heads = baselines.train_medusa_heads(
        model, params, heads, tasks.stream(TASK_CATEGORIES, 150, 16, 32,
                                           seed=13), lr=2e-3)

    dvi0 = online.init_trainer(model, jax.random.PRNGKey(21)).dvi_params

    runners = {
        "ar": lambda pr: spec.ar_generate(model, params, pr, MAX_NEW),
        "dvi": lambda pr: spec.speculative_generate(
            model, params, state.dvi_params, pr, MAX_NEW),
        "selfspec-static": lambda pr: spec.speculative_generate(
            model, params, dvi0, pr, MAX_NEW),
        "sps-2model": lambda pr: baselines.two_model_generate(
            model, params, draft, d_params, pr, MAX_NEW),
        "medusa-lite": lambda pr: baselines.medusa_generate(
            model, params, heads, pr, MAX_NEW),
    }
    runners = {k: jax.jit(v) for k, v in runners.items()}

    speedups = {k: [] for k in runners}
    for cat in TASK_CATEGORIES:
        prompts = jnp.asarray(tasks.sample(cat, EVAL_PROMPTS, PROMPT_LEN,
                                           seed=777))
        t_ar, _, _ = _gen_time(runners["ar"], prompts)
        for name, fn in runners.items():
            t, mat, toks = _gen_time(fn, prompts)
            sp = t_ar / t
            speedups[name].append(sp)
            emit(f"table2/{cat}/{name}", t * 1e6,
                 f"MAT={mat:.2f};speedup={sp:.2f}x")
    for name in runners:
        emit(f"table2/avg/{name}", 0.0,
             f"avg_speedup={np.mean(speedups[name]):.2f}x")


if __name__ == "__main__":
    main()
