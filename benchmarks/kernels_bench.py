"""Kernel micro-benchmarks: interpret-mode correctness timing vs the jnp
oracle (on TPU the same calls compile to Mosaic; here the derived column
reports the oracle-relative error so CI catches regressions)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels import ref
from repro.kernels.decode_attention import \
    decode_attention_pallas as decode_attention
from repro.kernels.lora_logits import lora_logits
from repro.kernels.paged_decode_attention import paged_decode_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.verify_argmax import verify_argmax


def main():
    h = jax.random.normal(jax.random.PRNGKey(0), (64, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 2048))
    t, (arg, mx) = timed(lambda: verify_argmax(h, w, block_t=64, block_v=512,
                                               interpret=True))
    arg_r, _ = ref.ref_verify_argmax(h, w)
    emit("kernel/verify_argmax", t * 1e6,
         f"match={bool(jnp.all(arg == arg_r))}")

    a = jax.random.normal(jax.random.PRNGKey(2), (128, 16))
    b = jax.random.normal(jax.random.PRNGKey(3), (16, 2048))
    t, out = timed(lambda: lora_logits(h, w, a, b, 2.0, block_t=64,
                                       block_v=512, interpret=True))
    err = float(jnp.abs(out - ref.ref_lora_logits(h, w, a, b, 2.0)).max())
    emit("kernel/lora_logits", t * 1e6, f"max_err={err:.2e}")

    q = jax.random.normal(jax.random.PRNGKey(4), (4, 16, 64))
    k = jax.random.normal(jax.random.PRNGKey(5), (4, 256, 4, 64))
    v = jax.random.normal(jax.random.PRNGKey(6), (4, 256, 4, 64))
    lens = jnp.full((4,), 200)
    t, o = timed(lambda: decode_attention(q, k, v, lens, block_s=64,
                                          interpret=True))
    err = float(jnp.abs(o - ref.ref_decode_attention(q, k, v, lens)).max())
    emit("kernel/decode_attention", t * 1e6, f"max_err={err:.2e}")

    # paged layout of the same cache: 4 pages/lane of 64 slots, shuffled
    ps, ppl = 64, 4
    perm = np.random.default_rng(0).permutation(4 * ppl) + 1
    tbl = jnp.asarray(perm.reshape(4, ppl).astype(np.int32))
    kp = jnp.concatenate([jnp.zeros((1, ps, 4, 64)),
                          k.reshape(4 * ppl, ps, 4, 64)])
    vp = jnp.concatenate([jnp.zeros((1, ps, 4, 64)),
                          v.reshape(4 * ppl, ps, 4, 64)])
    kp = kp.at[jnp.asarray(perm)].set(kp[1:])
    vp = vp.at[jnp.asarray(perm)].set(vp[1:])
    t, o = timed(lambda: paged_decode_attention(q, kp, vp, lens, tbl,
                                                interpret=True))
    err = float(jnp.abs(o - ref.ref_paged_decode_attention(
        q, kp, vp, lens, tbl)).max())
    emit("kernel/paged_decode_attention", t * 1e6, f"max_err={err:.2e}")

    # per-lane page early-out: SHORT lanes (here 1 of 16 pages ≈ 6% of
    # max_pages) should stop paying the full page-axis sweep.  Time the
    # trimmed kernel (page_counts from lengths, the default) against the
    # same kernel forced to sweep every page (page_counts = max_pages) —
    # identical outputs, the difference is pure skipped work.
    B, mps = 4, 16
    perm = np.random.default_rng(1).permutation(B * mps) + 1
    tbl_s = jnp.asarray(perm.reshape(B, mps).astype(np.int32))
    kp_s = jax.random.normal(jax.random.PRNGKey(12), (B * mps + 1, ps, 4, 64))
    vp_s = jax.random.normal(jax.random.PRNGKey(13), (B * mps + 1, ps, 4, 64))
    short = jnp.full((B,), ps)                       # 1 page of 16 per lane
    full_pc = jnp.full((B,), mps, jnp.int32)
    t_trim, o_trim = timed(lambda: paged_decode_attention(
        q, kp_s, vp_s, short, tbl_s, interpret=True))
    t_full, o_full = timed(lambda: paged_decode_attention(
        q, kp_s, vp_s, short, tbl_s, page_counts=full_pc, interpret=True))
    err = float(jnp.abs(o_trim - o_full).max())
    emit("kernel/paged_decode_early_out", t_trim * 1e6,
         f"full_sweep_us={t_full * 1e6:.0f} "
         f"speedup={t_full / max(t_trim, 1e-12):.2f}x max_err={err:.2e}")

    xh = jax.random.normal(jax.random.PRNGKey(7), (2, 128, 8, 32))
    Bc = jax.random.normal(jax.random.PRNGKey(8), (2, 128, 1, 64)) * 0.5
    Cc = jax.random.normal(jax.random.PRNGKey(9), (2, 128, 1, 64)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(10), (2, 128, 8)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(11), (8,)) * 0.3)
    t, (y, hf) = timed(lambda: ssd_scan(xh, Bc, Cc, dt, A, chunk=64,
                                        interpret=True))
    y_r, _ = ref.ref_ssd_scan(xh, Bc, Cc, dt, A, 64)
    emit("kernel/ssd_scan", t * 1e6,
         f"max_err={float(jnp.abs(y - y_r).max()):.2e}")


if __name__ == "__main__":
    main()
