"""Paper Table 3 + Figure 2: objective ablations.

Trains the drafter online with each single-term objective (KL-only = online
distillation, PG-only = REINFORCE, CE-only = reward-masked CE) plus the full
KL->RL schedule, on identical backbone/split/k_spec/data-stream, recording
the batch-acceptance learning curve (Fig. 2) and final Spec-Bench-style
MAT + speedup (Table 3).  Curves are written to experiments/fig2_curves.csv.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_backbone, emit, timed
from repro.core import online, spec
from repro.data import TASK_CATEGORIES

MODES = ["kl", "pg", "ce", "full"]
TRAIN_BATCHES = 120
MAX_NEW = 32


def main():
    cfg, model, params, tasks = bench_backbone(pretrain_steps=250)
    curves = {}
    finals = {}
    for mode in MODES:
        state = online.init_trainer(model, jax.random.PRNGKey(7))
        stream = tasks.stream(TASK_CATEGORIES, TRAIN_BATCHES, 8, 16, seed=11)
        state, hist = online.online_loop(model, params, stream, state,
                                         max_new=24, mode=mode, lr=3e-3)
        curves[mode] = hist["block_acc"]

        eval_prompts = jnp.asarray(tasks.sample("qa", 8, 16, seed=777))
        ar = jax.jit(lambda pr: spec.ar_generate(model, params, pr, MAX_NEW))
        dv = jax.jit(lambda pr: spec.speculative_generate(
            model, params, state.dvi_params, pr, MAX_NEW))
        t_ar, _ = timed(ar, eval_prompts)
        t_dv, res = timed(dv, eval_prompts)
        mat = float(res.committed) / max(float(res.blocks), 1.0)
        finals[mode] = (mat, t_ar / t_dv)
        emit(f"table3/{mode}", t_dv * 1e6,
             f"MAT={mat:.3f};speedup={t_ar/t_dv:.3f}x;"
             f"final_acc={np.mean(hist['block_acc'][-10:]):.3f}")

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/fig2_curves.csv", "w") as f:
        f.write("batch," + ",".join(MODES) + "\n")
        for i in range(TRAIN_BATCHES):
            f.write(f"{i}," + ",".join(f"{curves[m][i]:.4f}" for m in MODES)
                    + "\n")
    # paper-claim checks (directional)
    ok_kl = np.mean(curves["kl"][-10:]) > np.mean(curves["kl"][:10]) - 0.02
    ok_full = finals["full"][0] >= finals["kl"][0] - 0.05
    emit("table3/claims", 0.0,
         f"kl_improves={ok_kl};full_ge_kl={ok_full};"
         f"pg_final={np.mean(curves['pg'][-10:]):.3f};"
         f"ce_final={np.mean(curves['ce'][-10:]):.3f}")


if __name__ == "__main__":
    main()
