"""Serving-scheduler benchmark: sync (batch) vs continuous (slot) batching
— and optionally the paged-KV continuous scheduler — on the SAME Poisson
arrival trace: throughput, tail latency, and memory efficiency.

The sync scheduler buckets requests, pads the batch, and decodes everyone to
completion before admitting new work, so one long request holds the batch
hostage (head-of-line blocking) and arrivals wait for the next batch
boundary.  The continuous scheduler retires and admits per-slot every block,
so short requests stream out under long ones.  The ``--paged`` arm keeps
the continuous scheduler but swaps worst-case per-lane cache reservations
for the shared page pool at the SAME token-memory budget — which buys twice
the decode lanes, so it admits more concurrent requests per byte (the
``admitted_per_gb`` column).  All arms run the same unified
``spec_block_step`` core with online drafter updates.

  PYTHONPATH=src python benchmarks/serving_bench.py            # full
  PYTHONPATH=src python benchmarks/serving_bench.py --smoke    # CI job
  PYTHONPATH=src python benchmarks/serving_bench.py --paged --json out.json

Output: one CSV-ish line per scheduler:
  scheduler,requests,gen_tokens,tok_per_s,p50_ms,p95_ms,acceptance
plus (``--json``) a machine-readable record per arm with pool utilization /
preemption / concurrency stats for bench-trajectory tracking in CI.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from common import bench_backbone
from repro.core import online
from repro.models import transformer as tfm
from repro.serving import Request, ServingEngine
from repro.serving.kv_pool import pages_for

PROMPT_LENS = (8, 12, 16)
MAX_NEWS = (8, 16, 24)


def build_trace(n, rate_hz, tasks, vocab, seed=0):
    """Poisson arrivals with mixed prompt lengths and generation budgets."""
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate_hz, n))
    trace = []
    for i in range(n):
        tp = int(rng.choice(PROMPT_LENS))
        prompt = tasks.sample(rng.choice(["qa", "math"]), 1, tp,
                              seed=5000 + i)[0]
        trace.append((float(t[i]), Request(uid=i, prompt=prompt,
                                           max_new=int(rng.choice(MAX_NEWS)))))
    return trace


def kv_bytes_per_token(cfg) -> int:
    """KV-cache bytes per cached token (all layers, K+V)."""
    itemsize = 1 if cfg.kv_quant else cfg.jnp_dtype.itemsize
    return (cfg.num_layers * 2 * cfg.num_kv_heads * cfg.resolved_head_dim
            * itemsize)


def run_trace(scheduler, model, params, trace, num_slots, batch_size,
              warm=(), engine_kw=None):
    state = online.init_trainer(model, jax.random.PRNGKey(7))
    eng = ServingEngine(model, params, state, scheduler=scheduler,
                        num_slots=num_slots, batch_size=batch_size,
                        max_new=max(MAX_NEWS), buckets=(max(PROMPT_LENS),),
                        **(engine_kw or {}))
    # warm THIS engine's jit caches (they live in the engine instance) so the
    # timed run below pays no XLA compilation
    for _, wreq in warm:
        eng.submit(wreq)
    eng.run()
    eng.reset_stats()
    done = []
    i = 0
    t0 = time.perf_counter()
    while i < len(trace) or eng.busy:
        now = time.perf_counter() - t0
        while i < len(trace) and trace[i][0] <= now:
            eng.submit(trace[i][1])
            i += 1
        if not eng.busy:
            if i < len(trace):                 # idle until the next arrival
                time.sleep(min(trace[i][0] - now, 0.01))
            continue
        done.extend(eng.step())
    makespan = time.perf_counter() - t0
    return eng, done, makespan


def report(name, eng, done, makespan, token_budget=0):
    toks = sum(len(c.gen_tokens) for c in done)
    lat = eng.latency_percentiles()
    print(f"{name},{len(done)},{toks},{toks / makespan:.1f},"
          f"{lat['p50_s'] * 1e3:.0f},{lat['p95_s'] * 1e3:.0f},"
          f"{eng.acceptance:.3f}")
    rec = {"scheduler": name, "requests": len(done), "gen_tokens": toks,
           "tok_per_s": toks / makespan, "p50_ms": lat["p50_s"] * 1e3,
           "p95_ms": lat["p95_s"] * 1e3, "acceptance": eng.acceptance,
           "peak_live_slots": eng.stats.get("peak_live_slots", 0),
           "num_slots": eng.num_slots}
    if token_budget:
        gb = token_budget * kv_bytes_per_token(eng.model.cfg) / 2**30
        rec["kv_budget_tokens"] = token_budget
        rec["admitted_per_gb"] = len(done) / gb
    if eng.paged:
        rec["kv"] = eng.kv_stats()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: fewer requests, smaller backbone")
    ap.add_argument("--paged", action="store_true",
                    help="add a paged-KV continuous arm (equal token memory, "
                         "2x lanes)")
    ap.add_argument("--json", default="",
                    help="write per-arm records to this JSON file")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--rate", type=float, default=0.0, help="arrivals/sec")
    ap.add_argument("--num-slots", type=int, default=8)
    ap.add_argument("--kv-page-size", type=int, default=8)
    ap.add_argument("--kv-pages", type=int, default=0,
                    help="paged arm pool size (0 = match contiguous memory)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    n = args.requests or (8 if args.smoke else 48)
    pre = 40 if args.smoke else 250
    slots = min(args.num_slots, 4) if args.smoke else args.num_slots
    cfg, model, params, tasks = bench_backbone(pretrain_steps=pre,
                                               seed=args.seed)
    # warm-up requests: continuous admission jit-specializes per prompt
    # length, so cover every length (run_trace warms its own engine)
    warm = [(0.0, Request(uid=10**6 + j,
                          prompt=tasks.sample("qa", 1, tp, seed=j)[0],
                          max_new=4))
            for j, tp in enumerate(PROMPT_LENS)]

    rate = args.rate or (4.0 if args.smoke else 2.0)
    trace = build_trace(n, rate, tasks, cfg.vocab_size, seed=args.seed)
    print("scheduler,requests,gen_tokens,tok_per_s,p50_ms,p95_ms,acceptance")
    # contiguous cap per lane (mirror of ServingEngine.__post_init__)
    cap = (max(PROMPT_LENS) + max(MAX_NEWS) + cfg.dvi.k_spec + 2
           + tfm.RING_SLACK)
    budget = slots * cap                       # token-slots both arms share
    recs = [report("sync", *run_trace("sync", model, params, trace, slots,
                                      args.batch, warm=warm), budget),
            report("continuous", *run_trace(
                "continuous", model, params, trace, slots, args.batch,
                warm=warm), budget)]
    s_tp, s_p95 = recs[0]["tok_per_s"], recs[0]["p95_ms"]
    c_tp, c_p95 = recs[1]["tok_per_s"], recs[1]["p95_ms"]
    print(f"# continuous vs sync: {c_tp / max(s_tp, 1e-9):.2f}x throughput, "
          f"{s_p95 / max(c_p95, 1e-9):.2f}x lower p95")

    if args.paged:
        pages = args.kv_pages or pages_for(budget, args.kv_page_size)
        recs.append(report("paged", *run_trace(
            "continuous", model, params, trace, 2 * slots, args.batch,
            warm=warm, engine_kw={"kv_pages": pages,
                                  "kv_page_size": args.kv_page_size}),
            pages * args.kv_page_size))
        p = recs[-1]
        print(f"# paged vs continuous (equal kv memory, 2x lanes): "
              f"{p['tok_per_s'] / max(c_tp, 1e-9):.2f}x throughput, "
              f"peak_live {p['peak_live_slots']} vs "
              f"{recs[1]['peak_live_slots']}, "
              f"preemptions={p['kv']['preemptions']}, "
              f"peak_util={p['kv']['peak_utilization']:.2f}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"arms": recs, "requests": n, "rate_hz": rate,
                       "backbone": cfg.name,
                       "kv_bytes_per_token": kv_bytes_per_token(cfg)}, f,
                      indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
