"""Serving-scheduler benchmark: sync (batch) vs continuous (slot) batching
— per-block and fused-superstep — and optionally the paged-KV continuous
scheduler, all on the SAME Poisson arrival trace: throughput, tail latency,
dispatch/host-overhead breakdown, and memory efficiency.

The sync scheduler buckets requests, pads the batch, and decodes everyone to
completion before admitting new work, so one long request holds the batch
hostage (head-of-line blocking) and arrivals wait for the next batch
boundary.  The continuous scheduler retires and admits per-slot, so short
requests stream out under long ones.  The ``continuous-fused`` arm keeps
the same scheduler but fuses ``--sync-every`` speculative blocks into one
device dispatch (``spec_superstep``): EOS/budget/commit handling moves
in-graph and the host syncs once per superstep instead of once per block —
the per-arm records carry the breakdown (blocks/s, host-sync count per 100
blocks, host wait fraction) and the bench asserts the two arms' token
streams are IDENTICAL (the fusion is lossless by construction).  The
``--paged`` arm runs the fused scheduler over the shared page pool at the
SAME token-memory budget — which buys twice the decode lanes, so it admits
more concurrent requests per byte (the ``admitted_per_gb`` column).  All
arms run the same unified ``spec_block_step`` core with online drafter
updates.

The ``mixed-*`` arms race a long/short mixed-prompt trace with one-shot
vs chunked prefill (``--prefill-chunk``): chunking bounds the engine-tick
cadence (the ``tick_p95_ms`` / ``tick_max_ms`` jitter columns) because a
long prompt prefills one chunk per tick between decode supersteps instead
of stalling admission for its whole prefill — with, again, bit-identical
token streams (hard-asserted).

The ``--prefix-cache`` arms race a shared-prefix tenant trace (N tenants
x M requests over common system prompts) through the paged+chunked engine
cold vs with prefix caching ON at EQUAL pool size: warm admission splices
cached prompt pages (refcount sharing + copy-on-write tail) and prefills
only the uncached tail.  Reported: admitted/s, prefill tokens saved, hit
rate, tick p50/p95.  Hard-asserted: bit-identical committed streams, a
real saving (>=1.5x admitted/s OR >=50% prefill skipped), exact
hit/miss/lookup reconciliation, and a leak-free drain (refcounts back to
baseline, every page free or evictable-cached).

``--adaptive-k`` (with ``--k-min``/``--k-max``) switches the fused and
paged arms onto per-lane acceptance-driven speculation depth
(repro.core.schedule).  Greedy committed streams are depth-independent,
so the cross-arm stream assertions keep holding — adaptive K is purely a
compute/memory knob under this bench's greedy decoding.

``--drift`` runs the drift-trace suite INSTEAD of the scheduler arms: a
closed-loop batch driver over a qa->math topic shift, for frozen vs
online drafter x fixed vs adaptive K (sharing one phase-1-warmed
drafter), reporting acceptance / mean-accepted-tokens / blocks-per-s
before, at, and after the shift.  Hard-asserted: the online+adaptive arm
recovers acceptance after the shift, the frozen+adaptive arm sustains
higher post-shift blocks/s than frozen+fixed (depth throttles to the
floor once acceptance collapses, so each superstep drafts less), and the
online+adaptive streams are bit-identical to a sync_every=1 rerun.

  PYTHONPATH=src python benchmarks/serving_bench.py            # full
  PYTHONPATH=src python benchmarks/serving_bench.py --smoke    # CI job
  PYTHONPATH=src python benchmarks/serving_bench.py --paged --json out.json
  PYTHONPATH=src python benchmarks/serving_bench.py --drift --smoke

Output: one CSV-ish line per scheduler:
  scheduler,requests,gen_tokens,tok_per_s,blocks_per_s,p50_ms,p95_ms,acceptance
plus (``--json``) a machine-readable record per arm with pool utilization /
preemption / concurrency / dispatch stats for bench-trajectory tracking in
CI.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import time

import jax
import numpy as np

from common import bench_backbone
from repro.core import online
from repro.core import schedule as schedule_mod
from repro.models import transformer as tfm
from repro.serving import Request, ServingEngine
from repro.serving.kv_pool import pages_for

PROMPT_LENS = (8, 12, 16)
MAX_NEWS = (8, 16, 24)
# long/short mix for the chunked-prefill jitter arm: every third request
# carries a prompt several chunks long, stalling admission ticks unless
# prefill is chunked
MIXED_SHORT, MIXED_LONG = 8, 48
# bench-trajectory artifact schema; bump when record keys change shape so
# scripts/check_bench_regression.py can refuse incomparable baselines
# (v3: per-arm acceptance_rate + mean_accepted_tokens, adaptive-K block;
#  v4: per-arm `metrics` registry snapshot [dvi_serving_*/dvi_train_*],
#  drift arms carry a per-update `train_timeline`;
#  v5: prefix-cache arms [prefix-cold / prefix-cached] with
#  dvi_serving_prefix_* counters and a `prefix_cache` summary block)
SCHEMA_VERSION = 5
# shared-prefix trace: tenants share a system prompt this long; each
# request adds a short unique tail (page-aligned-ish so most of the shared
# prefix is full pages)
PREFIX_SYS_LEN, PREFIX_TAIL_LEN = 40, 8
# drift-trace suite: qa traffic shifts to math at batch DRIFT_SHIFT
DRIFT_PHASE1, DRIFT_PHASE2 = "qa", "math"


def git_sha() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"],
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:
        return "unknown"


def build_trace(n, rate_hz, tasks, vocab, seed=0):
    """Poisson arrivals with mixed prompt lengths and generation budgets."""
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate_hz, n))
    trace = []
    for i in range(n):
        tp = int(rng.choice(PROMPT_LENS))
        prompt = tasks.sample(rng.choice(["qa", "math"]), 1, tp,
                              seed=5000 + i)[0]
        trace.append((float(t[i]), Request(uid=i, prompt=prompt,
                                           max_new=int(rng.choice(MAX_NEWS)))))
    return trace


def build_mixed_trace(n, rate_hz, tasks, seed=0):
    """Poisson arrivals mixing long prompts (every 3rd request) with short
    ones — the head-of-line workload chunked prefill exists for."""
    rng = np.random.default_rng(seed + 17)
    t = np.cumsum(rng.exponential(1.0 / rate_hz, n))
    trace = []
    for i in range(n):
        tp = MIXED_LONG if i % 3 == 0 else MIXED_SHORT
        prompt = tasks.sample(rng.choice(["qa", "math"]), 1, tp,
                              seed=7000 + i)[0]
        trace.append((float(t[i]), Request(uid=i, prompt=prompt,
                                           max_new=int(rng.choice(MAX_NEWS)))))
    return trace


def build_prefix_trace(n_tenants, per_tenant, rate_hz, tasks, seed=0):
    """Poisson arrivals from `n_tenants` tenants: each tenant's requests
    share a PREFIX_SYS_LEN-token system prompt and differ only in a short
    unique tail — the workload prefix caching exists for.  Tenants
    interleave round-robin so the cache serves several chains at once."""
    rng = np.random.default_rng(seed + 29)
    n = n_tenants * per_tenant
    t = np.cumsum(rng.exponential(1.0 / rate_hz, n))
    sysp = [tasks.sample("qa", 1, PREFIX_SYS_LEN, seed=8000 + k)[0]
            for k in range(n_tenants)]
    trace = []
    for i in range(n):
        tail = tasks.sample("math", 1, PREFIX_TAIL_LEN, seed=8500 + i)[0]
        prompt = np.concatenate([sysp[i % n_tenants], tail]).astype(np.int32)
        trace.append((float(t[i]), Request(uid=i, prompt=prompt,
                                           max_new=int(rng.choice(MAX_NEWS)))))
    return trace


def kv_bytes_per_token(cfg) -> int:
    """KV-cache bytes per cached token (all layers, K+V)."""
    itemsize = 1 if cfg.kv_quant else cfg.jnp_dtype.itemsize
    return (cfg.num_layers * 2 * cfg.num_kv_heads * cfg.resolved_head_dim
            * itemsize)


def run_trace(scheduler, model, params, trace, num_slots, batch_size,
              warm=(), engine_kw=None):
    state = online.init_trainer(model, jax.random.PRNGKey(7))
    eng = ServingEngine(model, params, state, scheduler=scheduler,
                        num_slots=num_slots, batch_size=batch_size,
                        max_new=max(MAX_NEWS), buckets=(max(PROMPT_LENS),),
                        **(engine_kw or {}))
    # warm THIS engine's jit caches (they live in the engine instance) so the
    # timed run below pays no XLA compilation
    for _, wreq in warm:
        eng.submit_request(wreq)
    eng.run()
    eng.reset_stats()
    done = []
    i = 0
    busy_s = 0.0                               # engine time, arrival idle out
    t0 = time.perf_counter()
    while i < len(trace) or eng.busy:
        now = time.perf_counter() - t0
        while i < len(trace) and trace[i][0] <= now:
            eng.submit_request(trace[i][1])
            i += 1
        if not eng.busy:
            if i < len(trace):                 # idle until the next arrival
                time.sleep(min(trace[i][0] - now, 0.01))
            continue
        ts = time.perf_counter()
        done.extend(eng.step())
        busy_s += time.perf_counter() - ts
    makespan = time.perf_counter() - t0
    return eng, done, makespan, busy_s


def report(name, eng, done, makespan, busy_s, token_budget=0):
    toks = sum(len(c.gen_tokens) for c in done)
    lat = eng.latency_percentiles()
    # dispatch rate over ENGINE-BUSY time: arrival-gap idling is workload
    # idleness, not scheduler speed, and would dilute every arm equally.
    # `steps` (scheduler iterations = batch block-steps) is the unit the
    # superstep fusion accelerates — every iteration runs the same batched
    # compute; fusing amortizes dispatch + host sync across sync_every of
    # them.  Per-live-lane `blocks` stays in the record for MAT/acceptance.
    steps = eng.stats["steps"] or eng.stats["blocks"]   # sync arm: lane-blocks
    blocks_per_s = steps / max(busy_s, 1e-9)
    print(f"{name},{len(done)},{toks},{toks / makespan:.1f},"
          f"{blocks_per_s:.1f},{lat['p50_s'] * 1e3:.0f},"
          f"{lat['p95_s'] * 1e3:.0f},{eng.acceptance:.3f}")
    rec = {"scheduler": name, "requests": len(done), "gen_tokens": toks,
           "tok_per_s": toks / makespan, "p50_ms": lat["p50_s"] * 1e3,
           "p95_ms": lat["p95_s"] * 1e3, "acceptance": eng.acceptance,
           "peak_live_slots": eng.stats.get("peak_live_slots", 0),
           "num_slots": eng.num_slots,
           "blocks": eng.stats["blocks"], "steps": steps,
           "makespan_s": makespan, "busy_s": busy_s,
           "blocks_per_s": blocks_per_s,
           "lane_blocks_per_s": eng.stats["blocks"] / max(busy_s, 1e-9),
           "host_wait_frac": eng.stats["sync_wait_s"] / max(busy_s, 1e-9),
           # speculative-decoding quality: fraction of drafted tokens the
           # verifier accepted, and committed tokens per verify pass (MAT)
           "acceptance_rate": eng.acceptance,
           "mean_accepted_tokens": (eng.stats["committed"]
                                    / max(eng.stats["blocks"], 1))}
    if getattr(eng, "adaptive_k", False):
        rec["adaptive"] = {k: (v.tolist() if hasattr(v, "tolist") else v)
                           for k, v in eng.adaptive_stats().items()}
    if eng.scheduler == "continuous":
        rec["dispatch"] = eng.dispatch_stats()
        tick = eng.tick_percentiles()
        rec["tick_p50_ms"] = tick["p50_s"] * 1e3
        rec["tick_p95_ms"] = tick["p95_s"] * 1e3
        rec["tick_max_ms"] = tick["max_s"] * 1e3
    if token_budget:
        gb = token_budget * kv_bytes_per_token(eng.model.cfg) / 2**30
        rec["kv_budget_tokens"] = token_budget
        rec["admitted_per_gb"] = len(done) / gb
    if eng.paged:
        rec["kv"] = eng.kv_stats()
    # v4: full registry snapshot (dvi_serving_* / dvi_train_*) — the metrics
    # pipeline is always on (only the lifecycle tracer is opt-in), so every
    # arm's record is schema-checkable by scripts/check_metrics_schema.py
    rec["metrics"] = eng.metrics_snapshot()
    return rec


def streams(done):
    return {c.uid: c.gen_tokens.tolist() for c in done}


# ---------------------------------------------------------------------------
# Drift-trace suite: frozen vs online drafter x fixed vs adaptive K
# ---------------------------------------------------------------------------

def clone_trainer(ws):
    """Deep-copy the warm drafter so every arm starts from the same weights
    (engines mutate dvi_params / opt buffers in place)."""
    return online.OnlineTrainerState(
        dvi_params=jax.tree.map(lambda a: a, ws.dvi_params),
        opt_state=jax.tree.map(lambda a: a, ws.opt_state),
        buf=jax.tree.map(lambda a: a, ws.buf),
        baseline=ws.baseline, step=ws.step)


def run_drift_arm(model, params, tasks, warm_state, *, learn, adaptive,
                  n_batches, shift_at, batch, prompt_len, max_new,
                  sync_every, k_min=1, k_max=0):
    """Closed-loop batches over a topic shift; per-batch delta metrics.

    Every arm submits the SAME request schedule (uid -> prompt is
    deterministic), so token streams are comparable across arms."""
    # the drift suite pins the controller's acceptance band BETWEEN the
    # healthy phase-1 level (~0.8 here) and the degraded post-shift level
    # (~0.5-0.6: the un-tuned drafter still shares the verifier's trunk, so
    # agreement never collapses to zero on synthetic tasks).  The serving
    # default band [0.35, 0.70] treats 0.55 acceptance as worth drafting
    # deep for; this bench asks "does depth throttle when acceptance
    # degrades", so the band must separate the two regimes.
    kmax = k_max or model.cfg.dvi.k_spec
    dc = schedule_mod.DepthConfig(k_min=k_min, k_max=kmax, k_init=kmax,
                                  ema_alpha=0.3, hi=0.80, lo=0.60,
                                  cooldown=3, ema_init=0.75)
    eng = ServingEngine(model, params, clone_trainer(warm_state),
                        scheduler="continuous", num_slots=batch,
                        batch_size=batch, max_new=max_new,
                        buckets=(prompt_len,), learn=learn,
                        updates_per_batch=2, sync_every=sync_every,
                        adaptive_k=adaptive, k_min=k_min, k_max=k_max,
                        depth_cfg=dc if adaptive else None)
    # warm the jit caches at the starting depth so batch-0 timing is honest
    # (adaptive arms still compile shallower K_blk variants when depth first
    # drops — that lands in the at-shift window, which is why blocks/s
    # comparisons read the post-shift window)
    for j in range(batch):
        eng.submit_request(Request(uid=10**7 + j,
                                   prompt=tasks.sample(DRIFT_PHASE1, 1,
                                                       prompt_len,
                                                       seed=90 + j)[0],
                                   max_new=4))
    eng.run()
    eng.reset_stats()
    rows, done, uid = [], [], 0
    keys = ("accepted", "drafted", "committed", "blocks", "steps")
    for b in range(n_batches):
        cat = DRIFT_PHASE1 if b < shift_at else DRIFT_PHASE2
        for _ in range(batch):
            eng.submit_request(Request(uid=uid,
                                       prompt=tasks.sample(cat, 1, prompt_len,
                                                           seed=uid)[0],
                                       max_new=max_new))
            uid += 1
        before = {k: eng.stats[k] for k in keys}
        t0 = time.perf_counter()
        while eng.busy:
            done.extend(eng.step())
        dt = time.perf_counter() - t0
        d = {k: eng.stats[k] - before[k] for k in keys}
        rows.append({"batch": b,
                     "acceptance": d["accepted"] / max(d["drafted"], 1),
                     "mat": d["committed"] / max(d["blocks"], 1),
                     "blocks_per_s": d["steps"] / max(dt, 1e-9),
                     "mean_depth": d["drafted"] / max(d["blocks"], 1)})
    return eng, rows, done


def wmean(rows, sl, key):
    vals = [r[key] for r in rows[sl]]
    return float(np.mean(vals)) if vals else 0.0


def run_drift_suite(args, model, params, tasks):
    n = args.requests or (12 if args.smoke else 24)
    shift = max(3, n // 3)
    batch = 4 if args.smoke else 8
    plen, mnew, S = 12, 16, 2
    # warm the drafter on phase-1 traffic ONLY, so the shift is a real
    # distribution change for it
    warm = online.init_trainer(model, jax.random.PRNGKey(7))
    warm, _ = online.online_loop(
        model, params,
        tasks.stream((DRIFT_PHASE1,), 12 if args.smoke else 30, 8, plen,
                     seed=1),
        warm, max_new=mnew, lr=3e-3)

    kw = dict(n_batches=n, shift_at=shift, batch=batch, prompt_len=plen,
              max_new=mnew, k_min=args.k_min, k_max=args.k_max)
    arms = {}
    for label, learn, adaptive in (("frozen-fixed", False, False),
                                   ("frozen-adaptive", False, True),
                                   ("online-fixed", True, False),
                                   ("online-adaptive", True, True)):
        arms[label] = run_drift_arm(model, params, tasks, warm, learn=learn,
                                    adaptive=adaptive, sync_every=S, **kw)
    # losslessness: adaptive + fused vs the same arm one block at a time
    ref = run_drift_arm(model, params, tasks, warm, learn=True,
                        adaptive=True, sync_every=1, **kw)
    match = streams(arms["online-adaptive"][2]) == streams(ref[2])

    pre = slice(max(shift - 3, 0), shift)      # settled phase-1 traffic
    at = slice(shift, min(shift + 2, n))       # the drop (plus recompiles)
    post = slice(shift + 2, n)                 # settled post-shift regime
    late = slice(n - 3, n)                     # recovery endpoint
    print("arm,window,acceptance,mean_accepted_tokens,blocks_per_s,"
          "mean_depth")
    rec = {"shift_at": shift, "n_batches": n, "batch": batch,
           "sync_every": S, "streams_match": match, "arms": {}}
    for label, (eng, rows, _) in arms.items():
        wins = {}
        for wname, sl in (("pre", pre), ("at_shift", at), ("post", post),
                          ("late", late)):
            wins[wname] = {k: wmean(rows, sl, k)
                           for k in ("acceptance", "mat", "blocks_per_s",
                                     "mean_depth")}
            print(f"{label},{wname},{wins[wname]['acceptance']:.3f},"
                  f"{wins[wname]['mat']:.2f},"
                  f"{wins[wname]['blocks_per_s']:.1f},"
                  f"{wins[wname]['mean_depth']:.2f}")
        rec["arms"][label] = {"windows": wins, "curve": rows}
        if getattr(eng, "adaptive_k", False):
            rec["arms"][label]["adaptive"] = {
                k: (v.tolist() if hasattr(v, "tolist") else v)
                for k, v in eng.adaptive_stats().items()}
        # acceptance-recovery timeline: one row per drafter update (step,
        # schedule phase, loss components, EMA before/after) — the
        # dvi_train_* story of the recovery the window means summarize
        tt = eng.train_telemetry()
        rec["arms"][label]["train_timeline"] = tt["history"]
        rec["arms"][label]["metrics"] = eng.metrics_snapshot()
        if tt["updates"]:
            print(f"# {label} train: updates={tt['updates']} "
                  f"phase={tt['phase_name']} loss={tt['loss']:.4f} "
                  f"kl={tt['loss_kl']:.4f} ce={tt['loss_ce']:.4f} "
                  f"pg={tt['loss_pg']:.4f} "
                  f"acc_ema {tt['acceptance_ema_before']:.3f}->"
                  f"{tt['acceptance_ema_after']:.3f}")

    oa, ff, fa = (rec["arms"][k]["windows"]
                  for k in ("online-adaptive", "frozen-fixed",
                            "frozen-adaptive"))
    print(f"# online-adaptive acceptance: pre={oa['pre']['acceptance']:.3f} "
          f"at_shift={oa['at_shift']['acceptance']:.3f} "
          f"late={oa['late']['acceptance']:.3f}")
    print(f"# frozen post-shift blocks/s: fixed="
          f"{ff['post']['blocks_per_s']:.1f} adaptive="
          f"{fa['post']['blocks_per_s']:.1f} depth "
          f"{ff['post']['mean_depth']:.2f} -> "
          f"{fa['post']['mean_depth']:.2f}, streams_match={match}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema_version": SCHEMA_VERSION,
                       "git_sha": git_sha(), "mode": "drift",
                       "drift": rec, "backbone": model.cfg.name}, f,
                      indent=2)
        print(f"# wrote {args.json}")

    # hard gates (CI drift-smoke): the online+adaptive arm must RECOVER
    # acceptance after the shift, the frozen+adaptive arm must convert the
    # acceptance collapse into throughput (depth floor -> cheaper blocks),
    # and fused adaptive streams must equal the per-block schedule's.
    if not match:
        raise SystemExit("FATAL: adaptive fused streams diverged from the "
                         "per-block (sync_every=1) schedule")
    if not oa["late"]["acceptance"] > oa["at_shift"]["acceptance"]:
        raise SystemExit(
            f"FATAL: online+adaptive did not recover acceptance after the "
            f"shift (at_shift={oa['at_shift']['acceptance']:.3f}, "
            f"late={oa['late']['acceptance']:.3f})")
    if not fa["post"]["blocks_per_s"] > ff["post"]["blocks_per_s"]:
        raise SystemExit(
            f"FATAL: adaptive K did not raise post-shift blocks/s over "
            f"fixed K on the frozen drafter "
            f"(fixed={ff['post']['blocks_per_s']:.1f}, "
            f"adaptive={fa['post']['blocks_per_s']:.1f})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: fewer requests, smaller backbone")
    ap.add_argument("--paged", action="store_true",
                    help="add a paged-KV continuous arm (equal token memory, "
                         "2x lanes)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="add a shared-prefix tenant trace: paged+chunked "
                         "cold vs prefix-cached warm at EQUAL pool size; "
                         "hard-asserts bit-identical streams and a real "
                         "prefill saving")
    ap.add_argument("--drift", action="store_true",
                    help="run the drift-trace suite (frozen/online drafter x "
                         "fixed/adaptive K over a topic shift) instead of "
                         "the scheduler arms")
    ap.add_argument("--adaptive-k", action="store_true",
                    help="run the fused and paged arms with per-lane "
                         "acceptance-driven speculation depth")
    ap.add_argument("--k-min", type=int, default=1,
                    help="adaptive-k depth floor")
    ap.add_argument("--k-max", type=int, default=0,
                    help="adaptive-k depth ceiling (0 = cfg k_spec)")
    ap.add_argument("--json", default="",
                    help="write per-arm records to this JSON file")
    ap.add_argument("--telemetry", action="store_true",
                    help="run the fused (and paged) arms with the lifecycle "
                         "tracer on; hard-asserts the zero-host-sync "
                         "contract (host_syncs == dispatches, streams "
                         "bit-identical to the untraced per-block arm)")
    ap.add_argument("--trace-out", default="",
                    help="write the fused arm's Chrome/Perfetto trace here "
                         "(implies --telemetry)")
    ap.add_argument("--metrics-out", default="",
                    help="write the fused arm's metrics snapshot here "
                         "(.json = snapshot JSON, else Prometheus text)")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--rate", type=float, default=0.0, help="arrivals/sec")
    ap.add_argument("--num-slots", type=int, default=8)
    ap.add_argument("--sync-every", type=int, default=8,
                    help="blocks fused per device sync in the fused arm")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="chunk size for the mixed-trace chunked-prefill "
                         "arm (0 disables the mixed arms)")
    ap.add_argument("--kv-page-size", type=int, default=8)
    ap.add_argument("--kv-pages", type=int, default=0,
                    help="paged arm pool size (0 = match contiguous memory)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.trace_out:
        args.telemetry = True

    if args.sync_every < 2:
        ap.error("--sync-every must be >= 2: the per-block `continuous` arm "
                 "already runs sync_every=1, so a fused arm below 2 would "
                 "duplicate it")
    n = args.requests or (8 if args.smoke else 48)
    pre = 40 if args.smoke else 250
    slots = min(args.num_slots, 4) if args.smoke else args.num_slots
    S = args.sync_every
    cfg, model, params, tasks = bench_backbone(pretrain_steps=pre,
                                               seed=args.seed)
    if args.drift:
        run_drift_suite(args, model, params, tasks)
        return
    # per-lane adaptive depth for the fused + paged arms; the per-block and
    # sync arms stay fixed-K, and the cross-arm stream assertions still hold
    # because greedy committed streams are depth-independent
    adapt_kw = ({"adaptive_k": True, "k_min": args.k_min,
                 "k_max": args.k_max} if args.adaptive_k else {})
    # warm-up requests: continuous admission jit-specializes per prompt
    # length, so cover every length (run_trace warms its own engine)
    warm = [(0.0, Request(uid=10**6 + j,
                          prompt=tasks.sample("qa", 1, tp, seed=j)[0],
                          max_new=4))
            for j, tp in enumerate(PROMPT_LENS)]

    rate = args.rate or (4.0 if args.smoke else 2.0)
    trace = build_trace(n, rate, tasks, cfg.vocab_size, seed=args.seed)
    print("scheduler,requests,gen_tokens,tok_per_s,blocks_per_s,"
          "p50_ms,p95_ms,acceptance")
    # contiguous cap per lane (mirror of ServingEngine.__post_init__)
    cap = (max(PROMPT_LENS) + max(MAX_NEWS) + cfg.dvi.k_spec + 2
           + tfm.RING_SLACK)
    budget = slots * cap                       # token-slots both arms share
    # the fused (and paged) arms carry the lifecycle tracer when requested;
    # the per-block reference arm stays untraced so the stream comparison
    # below doubles as the telemetry bit-identity gate
    telem_kw = {"telemetry": True} if args.telemetry else {}
    c1 = run_trace("continuous", model, params, trace, slots, args.batch,
                   warm=warm, engine_kw={"sync_every": 1})
    cS = run_trace("continuous", model, params, trace, slots, args.batch,
                   warm=warm, engine_kw={"sync_every": S, **adapt_kw,
                                         **telem_kw})
    recs = [report("sync", *run_trace("sync", model, params, trace, slots,
                                      args.batch, warm=warm), budget),
            report("continuous", *c1, budget),
            report(f"continuous-fused-s{S}", *cS, budget)]
    s_tp, s_p95 = recs[0]["tok_per_s"], recs[0]["p95_ms"]
    c_tp, c_p95 = recs[1]["tok_per_s"], recs[1]["p95_ms"]
    print(f"# continuous vs sync: {c_tp / max(s_tp, 1e-9):.2f}x throughput, "
          f"{s_p95 / max(c_p95, 1e-9):.2f}x lower p95")

    # fused vs per-block: dispatch/host-overhead breakdown + losslessness
    match = streams(c1[1]) == streams(cS[1])
    d1, dS = recs[1]["dispatch"], recs[2]["dispatch"]
    sync_cut = (d1["host_syncs_per_100_blocks"]
                / max(dS["host_syncs_per_100_blocks"], 1e-9))
    fused_speedup = recs[2]["blocks_per_s"] / max(recs[1]["blocks_per_s"],
                                                  1e-9)
    print(f"# fused(s={S}) vs per-block: {fused_speedup:.2f}x blocks/s, "
          f"host-syncs/100blk {d1['host_syncs_per_100_blocks']:.1f} -> "
          f"{dS['host_syncs_per_100_blocks']:.1f} ({sync_cut:.1f}x fewer), "
          f"host_wait {recs[1]['host_wait_frac']:.2f} -> "
          f"{recs[2]['host_wait_frac']:.2f}, streams_match={match}")
    summary = {"fused_speedup_blocks_per_s": fused_speedup,
               "host_sync_reduction": sync_cut, "streams_match": match}

    if args.telemetry:
        # zero-host-sync contract: the tracer rides the ONE device_get per
        # superstep the engine already performs; any extra sync shows up as
        # host_syncs > dispatches.  Streams must also match the untraced
        # per-block arm (covered by `match` above) — both are hard gates.
        t_eng = cS[0]
        hs, dp = t_eng.stats["host_syncs"], t_eng.stats["dispatches"]
        if hs != dp:
            raise SystemExit(
                f"FATAL: telemetry added host syncs (host_syncs={hs}, "
                f"dispatches={dp}) — the zero-host-sync contract is broken")
        if not match:
            raise SystemExit(
                "FATAL: telemetry-on fused streams diverged from the "
                "untraced per-block scheduler")
        print(f"# telemetry: host_syncs={hs} == dispatches={dp}, "
              f"trace_events={len(t_eng.trace_dict()['traceEvents'])}, "
              f"streams_match={match}")
        summary["telemetry"] = {"host_syncs": hs, "dispatches": dp,
                                "streams_match": match}
        if args.trace_out:
            t_eng.write_trace(args.trace_out)
            print(f"# wrote {args.trace_out}")
        if args.metrics_out:
            t_eng.write_metrics(args.metrics_out)
            print(f"# wrote {args.metrics_out}")

    # mixed long/short-prompt trace: block-step cadence jitter with and
    # without chunked prefill.  Runs at a small superstep (latency-lean
    # serving) — that is where one-shot prefill stalls hurt the cadence
    # most.  The chunked arm must emit bit-identical streams.
    if args.prefill_chunk:
        C, Sm = args.prefill_chunk, 2
        n_mixed = max(6, n // 2)
        mixed = build_mixed_trace(n_mixed, rate, tasks, seed=args.seed)
        warm_mixed = [(0.0, Request(uid=10**6 + 50 + j,
                                    prompt=tasks.sample("qa", 1, tp,
                                                        seed=90 + j)[0],
                                    max_new=4))
                      for j, tp in enumerate((MIXED_SHORT, MIXED_LONG))]
        m1 = run_trace("continuous", model, params, mixed, slots, args.batch,
                       warm=warm_mixed, engine_kw={"sync_every": Sm})
        mC = run_trace("continuous", model, params, mixed, slots, args.batch,
                       warm=warm_mixed, engine_kw={"sync_every": Sm,
                                                   "prefill_chunk": C})
        recs.append(report(f"mixed-fused-s{Sm}", *m1))
        recs.append(report(f"mixed-chunked-c{C}", *mC))
        mixed_match = streams(m1[1]) == streams(mC[1])
        j0, jC = recs[-2], recs[-1]
        print(f"# mixed trace (chunk={C}): tick p95 "
              f"{j0['tick_p95_ms']:.0f}ms -> {jC['tick_p95_ms']:.0f}ms, "
              f"max {j0['tick_max_ms']:.0f}ms -> {jC['tick_max_ms']:.0f}ms, "
              f"chunk_steps={jC['dispatch']['prefill_chunks']}, "
              f"streams_match={mixed_match}")
        summary["prefill"] = {
            "chunk": C, "streams_match": mixed_match,
            "tick_p95_ms_oneshot": j0["tick_p95_ms"],
            "tick_p95_ms_chunked": jC["tick_p95_ms"],
            "tick_max_ms_oneshot": j0["tick_max_ms"],
            "tick_max_ms_chunked": jC["tick_max_ms"],
        }
        match = match and mixed_match

    if args.paged:
        pages = args.kv_pages or pages_for(budget, args.kv_page_size)
        recs.append(report("paged", *run_trace(
            "continuous", model, params, trace, 2 * slots, args.batch,
            warm=warm, engine_kw={"kv_pages": pages,
                                  "kv_page_size": args.kv_page_size,
                                  "sync_every": S, **adapt_kw, **telem_kw}),
            pages * args.kv_page_size))
        p = recs[-1]
        print(f"# paged vs continuous (equal kv memory, 2x lanes): "
              f"{p['tok_per_s'] / max(c_tp, 1e-9):.2f}x throughput, "
              f"peak_live {p['peak_live_slots']} vs "
              f"{recs[1]['peak_live_slots']}, "
              f"preemptions={p['kv']['preemptions']}, "
              f"peak_util={p['kv']['peak_utilization']:.2f}")
        if args.telemetry and (p["dispatch"]["host_syncs"]
                               != p["dispatch"]["dispatches"]):
            raise SystemExit(
                f"FATAL: telemetry added host syncs on the paged arm "
                f"(host_syncs={p['dispatch']['host_syncs']}, "
                f"dispatches={p['dispatch']['dispatches']})")

    # shared-prefix tenant trace: cold (paged + chunked) vs prefix-cached,
    # SAME trace, SAME pool size — the only difference is the cache.  The
    # committed streams must be bit-identical (sharing is a memory-layout
    # choice, never a numerics choice), and the warm arm must either admit
    # >= 1.5x faster or skip >= 50% of prefill work.
    if args.prefix_cache:
        ps = args.kv_page_size
        tenants, per_tenant = 2, (4 if args.smoke else 8)
        # one lane per tenant: the first admission wave (one request per
        # free slot) necessarily runs cold — publishing happens at prefill
        # completion — so more slots than tenants just manufactures misses
        # for requests that arrive before the first wave finishes.  Both
        # arms get the SAME slot count, so the comparison stays fair.
        pfx_slots = tenants
        pfx_trace = build_prefix_trace(tenants, per_tenant, rate, tasks,
                                       seed=args.seed)
        C = args.prefill_chunk or 8
        cap_pfx = (PREFIX_SYS_LEN + PREFIX_TAIL_LEN + max(MAX_NEWS)
                   + cfg.dvi.k_spec + 2 + tfm.RING_SLACK)
        pfx_pages = pages_for(pfx_slots * cap_pfx, ps) + pfx_slots
        warm_pfx = [(0.0, Request(
            uid=10**6 + 80 + j,
            prompt=tasks.sample("qa", 1, PREFIX_SYS_LEN + PREFIX_TAIL_LEN,
                                seed=70 + j)[0], max_new=4))
            for j in range(2)]
        pkw = {"kv_pages": pfx_pages, "kv_page_size": ps, "sync_every": S,
               "prefill_chunk": C}
        cold = run_trace("continuous", model, params, pfx_trace, pfx_slots,
                         args.batch, warm=warm_pfx, engine_kw=pkw)
        cached = run_trace("continuous", model, params, pfx_trace, pfx_slots,
                           args.batch, warm=warm_pfx,
                           engine_kw={**pkw, "prefix_cache": True})
        recs.append(report("prefix-cold", *cold))
        recs.append(report("prefix-cached", *cached))
        rc, rw = recs[-2], recs[-1]
        pfx_match = streams(cold[1]) == streams(cached[1])
        kvw = rw["kv"]
        # prefill work the cache skipped: hit tokens are spliced from the
        # pool instead of computed.  Engine-side counters (reset after the
        # warm-up phase, unlike the pool's own lifetime totals) keep the
        # measurement exact; pool sized so admission never retries a
        # blocked lookup.
        ws = cached[0].stats
        hits, lookups = ws["prefix_hits"], ws["prefix_lookups"]
        total_prefill = sum(len(r.prompt) - 1 for _, r in pfx_trace)
        saved = ws["prefix_hit_tokens"]
        saved_frac = saved / max(total_prefill, 1)
        admit_cold = rc["requests"] / max(rc["makespan_s"], 1e-9)
        admit_warm = rw["requests"] / max(rw["makespan_s"], 1e-9)
        admit_speedup = admit_warm / max(admit_cold, 1e-9)
        print(f"# prefix cache ({tenants} tenants x {per_tenant} reqs, "
              f"sys={PREFIX_SYS_LEN}): admitted/s {admit_cold:.2f} -> "
              f"{admit_warm:.2f} ({admit_speedup:.2f}x), prefill saved "
              f"{saved}/{total_prefill} ({saved_frac:.0%}), hits "
              f"{hits}/{lookups}, cow={ws['prefix_cow_copies']}, "
              f"tick p95 {rc['tick_p95_ms']:.0f}ms -> "
              f"{rw['tick_p95_ms']:.0f}ms, streams_match={pfx_match}")
        summary["prefix_cache"] = {
            "tenants": tenants, "per_tenant": per_tenant,
            "pool_pages": pfx_pages, "streams_match": pfx_match,
            "prefill_tokens_total": total_prefill,
            "prefill_tokens_saved": saved, "saved_frac": saved_frac,
            "admitted_per_s_cold": admit_cold,
            "admitted_per_s_cached": admit_warm,
            "admit_speedup": admit_speedup,
            "tick_p50_ms_cold": rc["tick_p50_ms"],
            "tick_p50_ms_cached": rw["tick_p50_ms"],
            "tick_p95_ms_cold": rc["tick_p95_ms"],
            "tick_p95_ms_cached": rw["tick_p95_ms"],
        }
        # hard gates: identity first, then the perf claim, then the
        # leak-free drain epilogue (refcounts back to baseline)
        if not pfx_match:
            raise SystemExit("FATAL: prefix-cached streams diverged from "
                             "cold prefill")
        if not (admit_speedup >= 1.5 or saved_frac >= 0.5):
            raise SystemExit(
                f"FATAL: prefix cache bought neither admission speed "
                f"(x{admit_speedup:.2f} < 1.5) nor prefill work "
                f"({saved_frac:.0%} < 50%)")
        if kvw["used_pages"] != 0 or (kvw["free_pages"] + kvw["cached_pages"]
                                      != kvw["num_pages"]):
            raise SystemExit(
                f"FATAL: pool did not drain to baseline (used="
                f"{kvw['used_pages']}, free={kvw['free_pages']}, "
                f"cached={kvw['cached_pages']}, num={kvw['num_pages']})")
        if hits + ws["prefix_misses"] != lookups:
            raise SystemExit("FATAL: prefix hit/miss counters do not "
                             "reconcile with lookups")

    if args.json:
        with open(args.json, "w") as f:
            # schema_version + git_sha stamp: bench-trajectory artifacts
            # from different PRs must be comparable (and refusable when
            # the schema moved) by scripts/check_bench_regression.py
            json.dump({"schema_version": SCHEMA_VERSION,
                       "git_sha": git_sha(),
                       "arms": recs, "requests": n, "rate_hz": rate,
                       "sync_every": S, "fused": summary,
                       "backbone": cfg.name,
                       "kv_bytes_per_token": kv_bytes_per_token(cfg)}, f,
                      indent=2)
        print(f"# wrote {args.json}")

    # the fusion is lossless BY CONSTRUCTION — a divergence is a
    # correctness regression, not a perf data point; fail the run (and CI)
    if not match:
        raise SystemExit("FATAL: fused token streams diverged from the "
                         "per-block scheduler")


if __name__ == "__main__":
    main()
