"""Paper Table 1: training-data budgets across SD methods.

The budget comparison is analytic (from the cited papers' protocols); the
measured quantity is the cost of ONE DVI optimizer step (generate-with-
logging amortized + LoRA update) on this machine, demonstrating that DVI's
whole training run is `prompt_exposures x that`.
"""
from __future__ import annotations

import jax

from benchmarks.common import bench_backbone, emit, timed
from repro.core import online

BUDGETS = [
    # method, sharegpt_samples, epochs, prompt_exposures, optimizer_steps
    ("DVI (this work)", 2_000, 1, 2_000, 2_000),
    ("Medusa",         60_000, 2, 120_000, 945),
    ("Kangaroo",       60_000, 20, 1_200_000, 4_700),
    ("EAGLE",          60_000, 40, 2_400_000, 300_000),
]


def main():
    cfg, model, params, tasks = bench_backbone(pretrain_steps=150)
    state = online.init_trainer(model, jax.random.PRNGKey(7))
    update = online.make_update_fn(model, "full", 1e-3)
    # one warm generate to fill the buffer
    from repro.core import spec as spec_mod
    prompts = jax.numpy.asarray(tasks.sample("qa", 8, 16, seed=1))
    res = spec_mod.speculative_generate(model, params, state.dvi_params,
                                        prompts, 16, collect=True,
                                        buf=state.buf)
    state.buf = res.buffer

    def one_update():
        return update(params, state.dvi_params, state.opt_state, state.buf,
                      state.baseline, state.step, jax.random.PRNGKey(0))

    t, _ = timed(one_update)
    base = BUDGETS[0][3]
    for name, samples, epochs, exposures, steps in BUDGETS:
        rel = exposures / base
        emit(f"table1/{name.split()[0].lower()}", t * 1e6,
             f"exposures={exposures};opt_steps={steps};rel_budget={rel:.0f}x")


if __name__ == "__main__":
    main()
