"""Roofline report (deliverable g): reads experiments/dryrun/*.json and
derives the three roofline terms per (arch x shape) on the single-pod mesh,
plus dominant bottleneck, MODEL_FLOPS ratio, and a what-would-move-it note.
Writes experiments/roofline.md and prints a benchmark CSV line per pair.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.configs import INPUT_SHAPES, get_config
from repro.launch.dryrun import adapt_config
from repro.roofline import roofline_from_record, suggestion


def load_records(path="experiments/dryrun", mesh="16x16"):
    recs = {}
    for f in glob.glob(os.path.join(path, f"*_{mesh}.json")):
        d = json.load(open(f))
        if "+" in d["arch"]:        # variant runs (e.g. +kvq) live in §Perf
            continue
        recs[(d["arch"], d["shape"])] = d
    return recs


def main():
    recs = load_records()
    rows = []
    for (arch, shape_name), rec in sorted(recs.items()):
        if rec["status"] == "skip":
            rows.append((arch, shape_name, None, rec.get("note", "")))
            continue
        if rec["status"] != "ok":
            rows.append((arch, shape_name, None,
                         "FAIL: " + rec.get("error", "")[:80]))
            continue
        cfg, _ = adapt_config(arch, INPUT_SHAPES[shape_name])
        rl = roofline_from_record(rec, cfg, INPUT_SHAPES[shape_name])
        rl["note"] = suggestion(rl)
        rows.append((arch, shape_name, rl, rec.get("note", "")))
        emit(f"roofline/{arch}/{shape_name}", rl["bound_s"] * 1e6,
             f"dominant={rl['dominant']};compute_s={rl['compute_s']:.3g};"
             f"memory_s={rl['memory_s']:.3g};"
             f"collective_s={rl['collective_s']:.3g};"
             f"useful_ratio={rl['useful_flops_ratio']:.2f};"
             f"peak_gib={rl['peak_mem_gib']:.1f}")

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline.md", "w") as f:
        f.write("# Roofline (single-pod 16x16, TPU v5e: 197 TF/s bf16, "
                "819 GB/s HBM, ~50 GB/s/link ICI)\n\n")
        f.write("| arch | shape | compute (s) | memory (s) | collective (s) "
                "| dominant | useful FLOP ratio | peak GiB/dev | fits | "
                "what moves it |\n|---|---|---|---|---|---|---|---|---|---|\n")
        for arch, shape, rl, note in rows:
            if rl is None:
                f.write(f"| {arch} | {shape} | — | — | — | skip/fail | — | — "
                        f"| — | {note} |\n")
                continue
            f.write(f"| {arch} | {shape} | {rl['compute_s']:.3g} | "
                    f"{rl['memory_s']:.3g} | {rl['collective_s']:.3g} | "
                    f"**{rl['dominant']}** | {rl['useful_flops_ratio']:.2f} | "
                    f"{rl['peak_mem_gib']:.1f} | "
                    f"{'y' if rl['fits_hbm'] else 'N'} | {rl['note']} |\n")
    print("# wrote experiments/roofline.md")


if __name__ == "__main__":
    main()
