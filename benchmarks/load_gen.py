"""llmperf-style open-loop load generator for the DVI API server.

Drives ``repro.launch.api_server`` over HTTP with OPEN-LOOP arrivals —
requests fire on a Poisson (or bursty on/off) schedule regardless of how
fast the server drains, which is what exposes queueing collapse (a
closed loop self-throttles and hides it).  Per-request knobs are drawn
from a seeded RNG: lognormal prompt/output lengths (quantized to keep
the jit compile-cache small — admission prefill specializes per prompt
length), a weighted tenant mix, and a cancel fraction (the client closes
the SSE socket mid-stream; the server must cancel the lane and reclaim
its pages at the next superstep boundary).

Reports TTFT / TPOT / E2E p50/p95/p99, throughput, and goodput against
an SLO (completed requests meeting BOTH the TTFT and E2E bounds), plus
completed/cancelled/rejected/error counts per tenant.

``--verify-direct`` replays every finished prompt through an in-process
engine built from the same ``ModelSpec`` and hard-asserts the SSE token
streams are bit-identical (completed) or an exact prefix (cancelled).
The direct engine deliberately uses a DIFFERENT scheduler config than
the server: greedy committed streams are schedule/drafter/depth
independent (the engine's losslessness contract), so any mismatch is a
transport or engine bug, not nondeterminism.  Cross-process determinism
needs PYTHONHASHSEED pinned to the server's (the synthetic pretrain
stream salts per-step seeds with ``hash()``).

  # terminal 1
  PYTHONHASHSEED=0 PYTHONPATH=src python -m repro.launch.api_server \\
      --port 8000 --tiny --max-queue 32
  # terminal 2
  PYTHONHASHSEED=0 PYTHONPATH=src python benchmarks/load_gen.py \\
      --port 8000 --requests 64 --rate 8 --tenants gold:3,free:1 \\
      --cancel-fraction 0.15 --verify-direct

``--smoke`` shrinks everything for CI (see .github/workflows/ci.yml).
"""
from __future__ import annotations

import argparse
import http.client
import json
import os
import sys
import threading
import time

import numpy as np

SCHEMA_VERSION = 1


# ---------------------------------------------------------------------------
# workload synthesis
# ---------------------------------------------------------------------------

def arrival_times(n: int, rate: float, pattern: str,
                  rng: np.random.Generator) -> list:
    """Cumulative arrival offsets (s).  ``poisson``: exponential gaps at
    `rate` req/s.  ``bursty``: on/off modulation — bursts of 6 requests
    at 3x rate, gaps at 0.3x — same mean load, heavier queue tails."""
    t, out = 0.0, []
    for i in range(n):
        r = rate
        if pattern == "bursty":
            r = rate * (3.0 if (i // 6) % 2 == 0 else 0.3)
        t += float(rng.exponential(1.0 / max(r, 1e-6)))
        out.append(t)
    return out


def draw_len(rng: np.random.Generator, mean: float, sigma: float,
             lo: int, hi: int, quantum: int = 4) -> int:
    """Lognormal length, clamped to [lo, hi] and rounded to `quantum`
    (every distinct prompt length is a separate prefill jit
    specialization — the palette keeps compile count bounded)."""
    v = float(rng.lognormal(np.log(max(mean, 1.0)), sigma))
    v = int(max(lo, min(hi, v)))
    return max(lo, (v // quantum) * quantum)


def parse_mix(spec: str) -> dict:
    out = {}
    for part in spec.split(","):
        name, _, w = part.partition(":")
        out[name.strip()] = float(w) if w else 1.0
    return out


# ---------------------------------------------------------------------------
# one HTTP request (SSE streaming client)
# ---------------------------------------------------------------------------

def run_request(host: str, port: int, rec: dict, timeout: float) -> dict:
    """Stream one completion; fills `rec` with outcome + timings.  A
    ``cancel_after`` mark closes the socket once that many tokens
    arrived — the server notices on its next SSE write and cancels."""
    body = json.dumps({
        "prompt": rec["prompt"], "max_tokens": rec["max_new"],
        "stream": True, "user": rec["tenant"],
        "priority": rec.get("priority", 0)})
    t_sub = time.monotonic()
    rec.update(outcome="error", tokens=[], t_submit=t_sub,
               ttft_s=None, tpot_s=None, e2e_s=None, status=0)
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request("POST", "/v1/completions", body,
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        rec["status"] = resp.status
        if resp.status == 429:
            rec["outcome"] = "rejected"
            return rec
        if resp.status != 200:
            rec["error"] = resp.read(200).decode(errors="replace")
            return rec
        toks, t_first, t_last, finish = [], None, None, None
        while True:
            line = resp.readline()
            if not line:
                break
            line = line.strip()
            if not line.startswith(b"data: "):
                continue
            payload = line[len(b"data: "):]
            if payload == b"[DONE]":
                break
            obj = json.loads(payload)
            if "error" in obj:
                rec["error"] = obj["error"].get("message", "?")
                return rec
            ch = obj["choices"][0]
            ids = ch.get("token_ids") or []
            if ids:
                now = time.monotonic()
                t_first = t_first if t_first is not None else now
                t_last = now
                toks.extend(ids)
            if ch.get("finish_reason"):
                finish = ch["finish_reason"]
            if (rec.get("cancel_after") is not None
                    and len(toks) >= rec["cancel_after"]):
                conn.close()
                rec.update(outcome="cancelled", tokens=toks,
                           finish_reason="client_closed")
                _fill_times(rec, t_first, t_last, toks)
                return rec
        rec.update(outcome="completed" if finish in ("stop", "length")
                   else ("cancelled" if finish == "cancelled" else "error"),
                   tokens=toks, finish_reason=finish)
        _fill_times(rec, t_first, t_last, toks)
        return rec
    except (OSError, http.client.HTTPException) as e:
        rec["error"] = repr(e)
        return rec
    finally:
        conn.close()


def _fill_times(rec: dict, t_first, t_last, toks) -> None:
    t_sub = rec["t_submit"]
    now = time.monotonic()
    if t_first is not None:
        rec["ttft_s"] = t_first - t_sub
        if len(toks) > 1 and t_last is not None and t_last > t_first:
            rec["tpot_s"] = (t_last - t_first) / (len(toks) - 1)
    rec["e2e_s"] = now - t_sub


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

def _pcts(vals: list) -> dict:
    xs = np.asarray([v for v in vals if v is not None], np.float64)
    if xs.size == 0:
        return {"p50_s": 0.0, "p95_s": 0.0, "p99_s": 0.0, "mean_s": 0.0,
                "count": 0}
    return {"p50_s": float(np.percentile(xs, 50)),
            "p95_s": float(np.percentile(xs, 95)),
            "p99_s": float(np.percentile(xs, 99)),
            "mean_s": float(np.mean(xs)), "count": int(xs.size)}


def build_report(args, recs: list, wall_s: float) -> dict:
    by = lambda o: [r for r in recs if r["outcome"] == o]  # noqa: E731
    completed = by("completed")
    gen_tokens = sum(len(r["tokens"]) for r in recs)
    good = [r for r in completed
            if r["ttft_s"] is not None and r["ttft_s"] <= args.slo_ttft
            and r["e2e_s"] is not None and r["e2e_s"] <= args.slo_e2e]
    tenants = {}
    for r in recs:
        t = tenants.setdefault(r["tenant"], {"submitted": 0, "completed": 0,
                                             "cancelled": 0, "rejected": 0,
                                             "error": 0})
        t["submitted"] += 1
        t[r["outcome"]] += 1
    return {
        "schema_version": SCHEMA_VERSION,
        "config": {
            "requests": args.requests, "rate": args.rate,
            "arrivals": args.arrivals, "tenants": args.tenants,
            "cancel_fraction": args.cancel_fraction,
            "slo_ttft_s": args.slo_ttft, "slo_e2e_s": args.slo_e2e,
            "workload_seed": args.workload_seed, "smoke": args.smoke,
        },
        "counts": {
            "submitted": len(recs), "completed": len(completed),
            "cancelled": len(by("cancelled")),
            "rejected": len(by("rejected")), "error": len(by("error")),
        },
        "wall_s": wall_s,
        "throughput_rps": len(completed) / max(wall_s, 1e-9),
        "gen_tokens": gen_tokens,
        "gen_tokens_per_s": gen_tokens / max(wall_s, 1e-9),
        "ttft": _pcts([r["ttft_s"] for r in completed]),
        "tpot": _pcts([r["tpot_s"] for r in completed]),
        "e2e": _pcts([r["e2e_s"] for r in completed]),
        "goodput": {
            "slo_ttft_s": args.slo_ttft, "slo_e2e_s": args.slo_e2e,
            "good_requests": len(good),
            "good_fraction": len(good) / max(len(completed), 1),
            "good_rps": len(good) / max(wall_s, 1e-9),
        },
        "tenants": tenants,
    }


def print_report(rep: dict) -> None:
    c = rep["counts"]
    print(f"[load] {c['submitted']} submitted: {c['completed']} completed, "
          f"{c['cancelled']} cancelled, {c['rejected']} rejected (429), "
          f"{c['error']} errors in {rep['wall_s']:.1f}s "
          f"({rep['gen_tokens_per_s']:.1f} tok/s)")
    for name in ("ttft", "tpot", "e2e"):
        p = rep[name]
        print(f"[load] {name:>4}: p50={p['p50_s']*1e3:8.1f}ms "
              f"p95={p['p95_s']*1e3:8.1f}ms p99={p['p99_s']*1e3:8.1f}ms "
              f"(n={p['count']})")
    g = rep["goodput"]
    print(f"[load] goodput: {g['good_requests']} requests within "
          f"SLO(ttft<={g['slo_ttft_s']}s, e2e<={g['slo_e2e_s']}s) = "
          f"{100 * g['good_fraction']:.1f}% of completed, "
          f"{g['good_rps']:.2f} req/s")
    for t, row in sorted(rep["tenants"].items()):
        print(f"[load] tenant {t!r}: {row}")


# ---------------------------------------------------------------------------
# engine-direct stream verification
# ---------------------------------------------------------------------------

def verify_direct(args, recs: list) -> dict:
    """Replay finished prompts through an in-process engine and compare
    token streams.  Greedy committed streams are schedule-independent, so
    the direct engine's config need not match the server's."""
    from repro.serving.config import ModelSpec, build_model_bundle
    from repro.serving.engine import Request, ServingEngine

    if os.environ.get("PYTHONHASHSEED") is None:
        print("[load] WARNING: PYTHONHASHSEED unset — the server and this "
              "process may have pretrained different params; pin it on "
              "both for --verify-direct", file=sys.stderr)
    spec = ModelSpec.from_args(args)
    print(f"[load] verify-direct: building {spec} ...", flush=True)
    _cfg, model, params, _tasks, state = build_model_bundle(spec)
    eng = ServingEngine(model, params, state, scheduler="continuous",
                        num_slots=4, max_new=args.output_max, learn=True,
                        sync_every=2)
    todo = [r for r in recs if r["outcome"] in ("completed", "cancelled")]
    handles = {}
    for i, r in enumerate(todo):
        handles[i] = eng.submit_request(Request(
            uid=i, prompt=np.asarray(r["prompt"], np.int32),
            max_new=r["max_new"]))
    eng.run(max_steps=100_000)
    mismatches = []
    for i, r in enumerate(todo):
        want = [int(t) for t in handles[i].tokens()]
        got = [int(t) for t in r["tokens"]]
        ok = (got == want if r["outcome"] == "completed"
              else got == want[:len(got)])   # cancelled: exact prefix
        if not ok:
            mismatches.append({"prompt": r["prompt"], "sse": got,
                               "direct": want, "outcome": r["outcome"]})
    out = {"checked": len(todo), "mismatches": len(mismatches),
           "detail": mismatches[:5]}
    if mismatches:
        print(f"[load] VERIFY FAILED: {len(mismatches)}/{len(todo)} "
              f"streams diverged from engine-direct decode",
              file=sys.stderr)
    else:
        print(f"[load] verify-direct: {len(todo)} streams bit-identical "
              f"to in-process decode")
    return out


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="open-loop load generator")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="mean arrival rate, req/s (open loop)")
    ap.add_argument("--arrivals", choices=("poisson", "bursty"),
                    default="poisson")
    ap.add_argument("--prompt-mean", type=float, default=24.0)
    ap.add_argument("--prompt-sigma", type=float, default=0.5)
    ap.add_argument("--prompt-max", type=int, default=64)
    ap.add_argument("--output-mean", type=float, default=16.0)
    ap.add_argument("--output-sigma", type=float, default=0.4)
    ap.add_argument("--output-max", type=int, default=32)
    ap.add_argument("--vocab", type=int, default=64,
                    help="prompt token ids drawn from [2, vocab)")
    ap.add_argument("--tenants", default="default:1",
                    help='traffic mix, e.g. "gold:3,free:1"')
    ap.add_argument("--cancel-fraction", type=float, default=0.0,
                    help="fraction of requests that close the socket "
                         "mid-stream (client-side cancel)")
    ap.add_argument("--slo-ttft", type=float, default=2.0)
    ap.add_argument("--slo-e2e", type=float, default=30.0)
    ap.add_argument("--timeout", type=float, default=120.0)
    ap.add_argument("--workload-seed", type=int, default=0,
                    help="arrivals/lengths/tenant-mix RNG (--seed is the MODEL seed)")
    ap.add_argument("--json", default=None, help="write the report here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny deterministic run for CI")
    ap.add_argument("--verify-direct", action="store_true",
                    help="hard-assert SSE streams == in-process decode")
    from repro.serving.config import ModelSpec
    ModelSpec.add_args(ap)
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests = min(args.requests, 10)
        args.rate = max(args.rate, 20.0)
        args.prompt_mean, args.prompt_max = 12.0, 16
        args.output_mean, args.output_max = 8.0, 12
        if args.tenants == "default:1":
            args.tenants = "smoke-a:2,smoke-b:1"
        if args.cancel_fraction == 0.0:
            args.cancel_fraction = 0.2

    rng = np.random.default_rng(args.workload_seed)
    mix = parse_mix(args.tenants)
    names = sorted(mix)
    weights = np.asarray([mix[n] for n in names], np.float64)
    weights /= weights.sum()
    arrivals = arrival_times(args.requests, args.rate, args.arrivals, rng)
    recs = []
    for i in range(args.requests):
        plen = draw_len(rng, args.prompt_mean, args.prompt_sigma, 4,
                        args.prompt_max)
        maxn = draw_len(rng, args.output_mean, args.output_sigma, 4,
                        args.output_max, quantum=1)
        cancel = rng.random() < args.cancel_fraction
        recs.append({
            "idx": i, "at": arrivals[i],
            "prompt": [int(t) for t in
                       rng.integers(2, args.vocab, size=plen)],
            "max_new": maxn,
            "tenant": names[int(rng.choice(len(names), p=weights))],
            "cancel_after": (max(1, maxn // 3) if cancel else None),
        })

    print(f"[load] open-loop: {args.requests} requests @ {args.rate} req/s "
          f"({args.arrivals}), tenants={args.tenants}, "
          f"cancel_fraction={args.cancel_fraction}", flush=True)
    threads = []
    t0 = time.monotonic()
    for r in recs:
        delay = t0 + r["at"] - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        th = threading.Thread(target=run_request,
                              args=(args.host, args.port, r, args.timeout))
        th.start()
        threads.append(th)
    for th in threads:
        th.join()
    wall = time.monotonic() - t0

    rep = build_report(args, recs, wall)
    print_report(rep)
    ok = rep["counts"]["completed"] > 0 and rep["counts"]["error"] == 0
    if args.verify_direct:
        rep["verify"] = verify_direct(args, recs)
        ok = ok and rep["verify"]["mismatches"] == 0
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rep, f, indent=1)
        print(f"[load] report written to {args.json}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
