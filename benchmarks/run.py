"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

  PYTHONPATH=src python -m benchmarks.run [--only table2,roofline] [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = ["kernels", "table1", "table2", "table3", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true",
                    help="reduced training budgets (smoke)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)

    print("name,us_per_call,derived")
    for suite in SUITES:
        if suite not in only:
            continue
        t0 = time.time()
        try:
            if suite == "kernels":
                from benchmarks import kernels_bench
                kernels_bench.main()
            elif suite == "table1":
                from benchmarks import table1_budget
                table1_budget.main()
            elif suite == "table2":
                from benchmarks import table2_specbench
                table2_specbench.main(train_batches=40 if args.fast else 150)
            elif suite == "table3":
                from benchmarks import table3_ablations
                if args.fast:
                    table3_ablations.TRAIN_BATCHES = 30
                table3_ablations.main()
            elif suite == "roofline":
                from benchmarks import roofline_report
                roofline_report.main()
        except Exception:   # noqa: BLE001 — report and continue
            print(f"{suite}/ERROR,0,{traceback.format_exc().splitlines()[-1]}",
                  file=sys.stderr)
            traceback.print_exc()
        print(f"# {suite} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
