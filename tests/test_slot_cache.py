"""Per-slot cache surgery (continuous batching): inserting a freshly
prefilled sequence into lane i of a live batched cache, or resetting a lane
on eviction, must leave every OTHER lane's KV / ring slots / stateful-mixer
states bit-identical — and the inserted lane must decode exactly as a solo
run would."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.configs.base import DVIConfig
from repro.core import lora, spec
from repro.models.model import build_model
import repro.models.transformer as tfm

# full attention, sliding-window ring, SSD state, RG-LRU state
SURGERY_ARCHS = ["vicuna-7b", "swa-ring", "mamba2-370m", "recurrentgemma-9b"]


def _build(name):
    if name == "swa-ring":
        cfg = tiny_cfg("qwen3-0.6b").replace(
            name="swa-ring", sliding_window=16, global_attn_every=0,
            num_layers=2, dvi=DVIConfig(split_layer=1, k_spec=3, lora_rank=8,
                                        buffer_slots=256, batch_size=32))
    else:
        cfg = tiny_cfg(name)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    return cfg, model, params, dvi


def _assert_other_lanes_identical(c1, c2, slot, B):
    """Every cache leaf bit-identical outside lane `slot` (batch axis is 0
    for `lengths`/`pos`, 1 for the layer-stacked leaves)."""
    for (p1, l1), (p2, l2) in zip(jax.tree_util.tree_leaves_with_path(c1),
                                  jax.tree_util.tree_leaves_with_path(c2)):
        name = jax.tree_util.keystr(p1)
        assert name == jax.tree_util.keystr(p2)
        ax = 0 if ("lengths" in name or "pos" in name) else 1
        for b in range(B):
            if b == slot:
                continue
            a = np.asarray(jnp.take(l1, b, axis=ax))
            c = np.asarray(jnp.take(l2, b, axis=ax))
            np.testing.assert_array_equal(a, c, err_msg=f"{name} lane {b}")


@pytest.mark.parametrize("name", SURGERY_ARCHS)
def test_insert_and_reset_leave_other_slots_bit_identical(name):
    cfg, model, params, dvi = _build(name)
    B, slot = 3, 1
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 2,
                                 cfg.vocab_size)
    _, cache, _ = model.prefill(params, prompts[:, :-1], max_len=64)
    pending = prompts[:, -1]
    for _ in range(3):                     # advance mid-decode (ring wraps for
        blk = spec.spec_block_step(model, params, dvi, pending, cache)
        pending, cache = blk.pending, blk.cache    # the W=16 config)

    newp = jax.random.randint(jax.random.PRNGKey(9), (1, 5), 2, cfg.vocab_size)
    _, pc, _ = model.prefill(params, newp[:, :-1], max_len=64)
    c_ins = tfm.insert_slot(cfg, cache, pc, jnp.int32(slot))
    _assert_other_lanes_identical(cache, c_ins, slot, B)
    assert int(c_ins["lengths"][slot]) == 4

    c_rst = tfm.reset_slot(cfg, c_ins, jnp.int32(slot))
    _assert_other_lanes_identical(c_ins, c_rst, slot, B)
    assert int(c_rst["lengths"][slot]) == 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(c_rst["segs"]):
        nm = jax.tree_util.keystr(path)
        ax = 0 if "pos" in nm else 1
        lane = np.asarray(jnp.take(leaf, slot, axis=ax))
        if "pos" in nm:
            assert (lane == -1).all(), f"{nm} not emptied"
        else:
            assert (lane == 0).all(), f"{nm} not zeroed"


@pytest.mark.parametrize("name", SURGERY_ARCHS)
def test_inserted_slot_decodes_like_solo_run(name):
    cfg, model, params, dvi = _build(name)
    B, slot = 3, 1
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 2,
                                 cfg.vocab_size)
    _, cache, _ = model.prefill(params, prompts[:, :-1], max_len=64)
    pending = prompts[:, -1]
    for _ in range(3):
        blk = spec.spec_block_step(model, params, dvi, pending, cache)
        pending, cache = blk.pending, blk.cache

    newp = jax.random.randint(jax.random.PRNGKey(9), (1, 5), 2, cfg.vocab_size)
    _, pc, _ = model.prefill(params, newp[:, :-1], max_len=64)
    cache = tfm.insert_slot(cfg, cache, pc, jnp.int32(slot))
    pending = jnp.where(jnp.arange(B) == slot,
                        jnp.broadcast_to(newp[:, -1], (B,)), pending)
    got = []
    for _ in range(4):
        blk = spec.spec_block_step(model, params, dvi, pending, cache)
        pending, cache = blk.pending, blk.cache
        got.extend(np.asarray(
            blk.commit_vec[slot, :int(blk.accept[slot])]).tolist())
    r = spec.ar_generate(model, params, newp, 16)
    ref = np.asarray(r.tokens[0, 5:int(r.lengths[0])]).tolist()
    n = min(len(got), len(ref))
    assert got[:n] == ref[:n], f"{name}: mid-batch insert diverged from solo"
