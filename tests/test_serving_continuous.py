"""Continuous-batching slot scheduler: per-request outputs are EXACTLY the
per-request greedy AR target stream, regardless of arrival order, mixed
prompt lengths, or mixed max_new; the sync path no longer pollutes training
signal with batch-padding duplicates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core import lora, online, spec
from repro.models.model import build_model
from repro.serving import Completion, Request, ServingEngine


@pytest.fixture(scope="module")
def backbone():
    cfg = tiny_cfg("vicuna-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _ragged_requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        Tp = int(rng.choice([6, 9, 12]))
        mn = int(rng.choice([6, 10, 16]))
        p = np.asarray(jax.random.randint(jax.random.PRNGKey(100 + i), (Tp,),
                                          2, cfg.vocab_size), np.int32)
        reqs.append(Request(uid=i, prompt=p, max_new=mn))
    return reqs


def _ar_reference(model, params, req, eos=1):
    r = spec.ar_generate(model, params, jnp.asarray(req.prompt)[None, :],
                         req.max_new)
    gen = np.asarray(r.tokens[0, len(req.prompt):int(r.lengths[0])]).tolist()
    out = []
    for t in gen[:req.max_new]:
        out.append(int(t))
        if t == eos:
            break
    return out


@pytest.mark.parametrize("order_seed", [0, 3])
def test_continuous_lossless_any_arrival_order(backbone, order_seed):
    cfg, model, params = backbone
    reqs = _ragged_requests(cfg, 7)
    order = np.random.default_rng(order_seed).permutation(len(reqs))
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    eng = ServingEngine(model, params, state, scheduler="continuous",
                        num_slots=3, max_new=16, buckets=(16,))
    for i in order:
        eng.submit(reqs[i])
    outs = eng.run(max_steps=500)
    assert len(outs) == len(reqs)
    assert not eng.busy
    by_uid = {o.uid: o for o in outs}
    for req in reqs:
        ref = _ar_reference(model, params, req)
        got = by_uid[req.uid].gen_tokens.tolist()
        assert got == ref, f"uid {req.uid}: {got} != AR {ref}"
        full = by_uid[req.uid].tokens
        np.testing.assert_array_equal(full[:len(req.prompt)], req.prompt)


def test_continuous_streams_and_tracks_latency(backbone):
    cfg, model, params = backbone
    reqs = _ragged_requests(cfg, 6, seed=5)
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    eng = ServingEngine(model, params, state, scheduler="continuous",
                        num_slots=2, max_new=16, update_every=2)
    for r in reqs:
        eng.submit(r)
    seen_partial = False
    done = []
    for _ in range(500):
        if not eng.busy:
            break
        out = eng.step()
        done.extend(out)
        # completions stream out while other requests are still in flight
        if out and eng.busy:
            seen_partial = True
    assert len(done) == len(reqs)
    assert seen_partial, "no streaming: all completions arrived at once"
    assert eng.stats["updates"] > 0          # cadence-driven drafter updates
    lat = eng.latency_percentiles()
    assert lat["p95_s"] >= lat["p50_s"] > 0.0
    assert len(eng.stats["latencies"]) == len(reqs)
    assert eng.slot_acceptance.shape == (2,)
    assert int(eng.stats["requests"]) == len(reqs)


def test_sync_padding_masked_out_of_collection(backbone):
    """A short sync batch is padded with duplicate requests; padded lanes
    must contribute no replay tuples and no draft/accept counters."""
    cfg, model, params = backbone
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 2,
                                cfg.vocab_size)
    pp = jnp.concatenate([prompt, prompt], axis=0)
    full = spec.speculative_generate(model, params, dvi, pp, 12, collect=True)
    half = spec.speculative_generate(model, params, dvi, pp, 12, collect=True,
                                     live_mask=jnp.array([True, False]))
    # identical duplicated lanes: masking one must exactly halve everything
    assert int(full.buffer["count"]) == 2 * int(half.buffer["count"])
    assert int(full.drafted) == 2 * int(half.drafted)
    assert int(full.committed) == 2 * int(half.committed)
    # masked lane generated nothing
    assert int(half.lengths[1]) == 8


def test_sync_engine_short_batch_stats(backbone):
    """End-to-end: 3 requests into a batch of 4 must produce EXACTLY the same
    stats as the same 3 requests in a batch of 3 — the padded duplicate lane
    contributes nothing."""
    cfg, model, params = backbone

    def serve(batch_size):
        state = online.init_trainer(model, jax.random.PRNGKey(3))
        eng = ServingEngine(model, params, state, scheduler="sync",
                            batch_size=batch_size, max_new=8, buckets=(8,),
                            learn=False)
        for i in range(3):
            p = np.asarray(jax.random.randint(jax.random.PRNGKey(i), (8,), 2,
                                              cfg.vocab_size), np.int32)
            eng.submit(Request(uid=i, prompt=p, max_new=8))
        return eng, eng.run()

    eng4, outs4 = serve(4)
    eng3, outs3 = serve(3)
    assert len(outs4) == len(outs3) == 3
    assert eng4.stats["requests"] == 3
    for k in ("blocks", "committed", "accepted", "drafted"):
        assert eng4.stats[k] == eng3.stats[k], k
    assert int(eng4.state.buf["count"]) == int(eng3.state.buf["count"])
    assert all(isinstance(o, Completion) and o.latency_s > 0 for o in outs4)
