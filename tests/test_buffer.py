"""Replay buffer invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import buffer as B


def _buf(slots=16):
    cfg = get_config("vicuna-7b", tiny=True)
    return B.init_buffer(cfg, slots=slots), cfg


@given(st.lists(st.integers(1, 10), min_size=1, max_size=8))
@settings(max_examples=20, deadline=None)
def test_count_and_ptr_track_valid_writes(block_sizes):
    buf, cfg = _buf(slots=16)
    d = cfg.d_model
    total = 0
    for i, n in enumerate(block_sizes):
        N = 12
        valid = jnp.arange(N) < n
        buf = B.add_block(
            buf,
            jnp.full((N, d), float(i)), jnp.full((N, d), float(i)),
            jnp.full((N,), i), jnp.ones((N,)),
            jnp.arange(N) + 1, jnp.zeros((N,), jnp.int32), valid)
        total += n
    assert int(buf["count"]) == min(total, 16)
    assert int(buf["ptr"]) == total % 16


def test_wraparound_keeps_newest():
    buf, cfg = _buf(slots=8)
    d = cfg.d_model
    for i in range(4):
        buf = B.add_block(
            buf, jnp.full((4, d), float(i)), jnp.full((4, d), float(i)),
            jnp.full((4,), i), jnp.ones((4,)), jnp.arange(4) + 1,
            jnp.zeros((4,), jnp.int32), jnp.ones((4,), bool))
    # 16 written into 8 slots -> actions present are from blocks 2 and 3
    acts = set(np.asarray(buf["action"]).tolist())
    assert acts == {2, 3}
    fresh = B.fresh_batch(buf, 4)
    assert np.asarray(fresh["action"]).tolist() == [3, 3, 3, 3]
    assert np.asarray(fresh["mask"]).sum() == 4


def test_sample_masks_when_underfull():
    buf, cfg = _buf(slots=16)
    d = cfg.d_model
    buf = B.add_block(buf, jnp.zeros((4, d)), jnp.zeros((4, d)),
                      jnp.zeros((4,), jnp.int32), jnp.ones((4,)),
                      jnp.arange(4) + 1, jnp.zeros((4,), jnp.int32),
                      jnp.ones((4,), bool))
    batch = B.sample(buf, jax.random.PRNGKey(0), 8)
    # with count=4, sampled indices < 4 are valid; mask reflects validity
    assert batch["mask"].shape == (8,)
    assert float(batch["mask"].sum()) == 8  # idx drawn in [0, count) -> all valid


def test_counterfactual_rows_never_written():
    buf, cfg = _buf(slots=16)
    d = cfg.d_model
    valid = jnp.array([True, True, False, False])
    buf = B.add_block(buf, jnp.ones((4, d)), jnp.ones((4, d)),
                      jnp.full((4,), 9), jnp.ones((4,)), jnp.arange(4) + 1,
                      jnp.zeros((4,), jnp.int32), valid)
    assert int(buf["count"]) == 2
    assert np.asarray(buf["action"])[:2].tolist() == [9, 9]
    assert np.asarray(buf["action"])[2:].sum() == 0
