"""Minimal deterministic stand-in for `hypothesis` when it isn't installed.

The container image doesn't ship hypothesis (and the repo can't add deps),
so conftest.py registers this module as `hypothesis` in sys.modules when the
real one is missing.  It implements just the surface the tests use —
``given``, ``settings``, ``strategies.integers/lists/sampled_from`` — and
runs a fixed-seed sample of examples instead of adaptive search, so the
property tests still exercise many random cases, reproducibly.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


def lists(elements, min_size=0, max_size=10):
    return _Strategy(lambda rng: [elements.example(rng)
                                  for _ in range(rng.randint(min_size,
                                                             max_size))])


def settings(**kwargs):
    def deco(fn):
        fn._hyp_settings = dict(kwargs)
        return fn
    return deco


def given(*strategies_args):
    def deco(fn):
        # drawn values bind to the LAST len(strategies) parameters; earlier
        # parameters stay visible to pytest as fixtures
        params = list(inspect.signature(fn).parameters.values())
        split = len(params) - len(strategies_args)
        drawn_names = [p.name for p in params[split:]]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # settings() may wrap either side of given(); check both
            conf = getattr(wrapper, "_hyp_settings",
                           getattr(fn, "_hyp_settings", {}))
            n = conf.get("max_examples", DEFAULT_EXAMPLES)
            rng = random.Random(0)
            for _ in range(n):
                drawn = {nm: s.example(rng)
                         for nm, s in zip(drawn_names, strategies_args)}
                fn(*args, **kwargs, **drawn)
        wrapper.__signature__ = inspect.Signature(parameters=params[:split])
        return wrapper
    return deco


def install():
    """Register this module as `hypothesis` (with a `strategies` submodule)."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.lists = lists
    strategies.sampled_from = sampled_from
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
