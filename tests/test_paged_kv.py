"""Paged KV subsystem: (1) paged-vs-contiguous generation is TOKEN-IDENTICAL
— greedy and rejection-sampled — across attn / sliding-window-ring / SSD /
RG-LRU mixers; (2) the page pool's alloc/free invariants hold under random
op sequences (no leak, no double-grant); (3) the continuous engine over the
paged pool is lossless even when scarcity forces preemption; (4) at equal
token-memory the paged scheduler admits strictly more concurrent requests
than contiguous worst-case reservation can."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import tiny_cfg
from repro.configs.base import DVIConfig
from repro.core import lora, online, spec
from repro.models.model import build_model
import repro.models.transformer as tfm
from repro.serving import Request, ServingEngine
from repro.serving.kv_pool import KVPool, pages_for

SURGERY_ARCHS = ["vicuna-7b", "swa-ring", "mamba2-370m", "recurrentgemma-9b"]


def _build(name):
    if name == "swa-ring":
        cfg = tiny_cfg("qwen3-0.6b").replace(
            name="swa-ring", sliding_window=16, global_attn_every=0,
            num_layers=2, dvi=DVIConfig(split_layer=1, k_spec=3, lora_rank=8,
                                        buffer_slots=256, batch_size=32))
    else:
        cfg = tiny_cfg(name)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    return cfg, model, params, dvi


def _grow(cache, pool, lanes_needed, ps, mps):
    """Engine-style on-demand growth: top each lane up to `lanes_needed[b]`
    token capacity.  Round-robin across lanes so physical pages interleave —
    the strongest layout for catching indexing bugs."""
    for b, need_tokens in enumerate(lanes_needed):
        need = pages_for(need_tokens, ps)
        have = len(pool.owned(b))
        if need > have:
            got = pool.alloc(need - have, owner=b)
            assert got is not None, "test pool sized too small"
            row = np.full(mps, -1, np.int32)
            owned = pool.owned(b)
            row[:len(owned)] = owned
            cache = tfm.map_slot_pages(cache, jnp.int32(b), jnp.asarray(row))
    return cache


# ---------------------------------------------------------------------------
# 1) paged == contiguous, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", SURGERY_ARCHS)
@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_paged_matches_contiguous_stream(name, temperature):
    """Same model, same prompts, same PRNG keys: the committed streams of
    the paged and contiguous caches must agree block by block — greedy
    (argmax) and rejection-sampled (Leviathan) alike."""
    cfg, model, params, dvi = _build(name)
    K = cfg.dvi.k_spec
    B, Tp, ps, mps = 3, 8, 4, 16
    pool = KVPool(num_pages=3 * mps, page_size=ps)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, Tp), 2,
                                 cfg.vocab_size)

    _, c_cache, _ = model.prefill(params, prompts[:, :-1], max_len=64)
    c_pending = prompts[:, -1]

    p_cache = model.init_paged_cache(B, pool.num_pages, ps, mps)
    p_cache = _grow(p_cache, pool, [Tp - 1 + K + 2] * B, ps, mps)
    for b in range(B):
        _, pc, _ = model.prefill(params, prompts[b:b + 1, :-1],
                                 max_len=Tp - 1)
        p_cache = tfm.insert_slot(cfg, p_cache, pc, jnp.int32(b))
    p_pending = prompts[:, -1]

    ck = pk = jax.random.PRNGKey(42)
    lens = [Tp - 1] * B
    for i in range(5):
        p_cache = _grow(p_cache, pool, [t + K + 2 for t in lens], ps, mps)
        cb = spec.spec_block_step(model, params, dvi, c_pending, c_cache,
                                  temperature=temperature, key=ck)
        pb = spec.spec_block_step(model, params, dvi, p_pending, p_cache,
                                  temperature=temperature, key=pk)
        c_pending, c_cache, ck = cb.pending, cb.cache, cb.key
        p_pending, p_cache, pk = pb.pending, pb.cache, pb.key
        np.testing.assert_array_equal(np.asarray(cb.accept),
                                      np.asarray(pb.accept),
                                      err_msg=f"{name} block {i}")
        for b in range(B):
            np.testing.assert_array_equal(
                np.asarray(cb.commit_vec[b, :int(cb.accept[b])]),
                np.asarray(pb.commit_vec[b, :int(pb.accept[b])]),
                err_msg=f"{name} block {i} lane {b}")
        lens = [t + int(cb.accept[b]) for b, t in enumerate(lens)]


def test_reset_slot_unmaps_paged_lane():
    cfg, model, params, dvi = _build("vicuna-7b")
    B, ps, mps = 2, 4, 8
    pool = KVPool(num_pages=16, page_size=ps)
    cache = model.init_paged_cache(B, pool.num_pages, ps, mps)
    cache = _grow(cache, pool, [10, 10], ps, mps)
    assert (np.asarray(cache["tbl"])[0] >= 0).sum() == pages_for(10, ps)
    cache = tfm.reset_slot(cfg, cache, jnp.int32(0))
    tbl = np.asarray(cache["tbl"])
    assert (tbl[0] == -1).all(), "evicted lane still mapped"
    assert (tbl[1] >= 0).sum() == pages_for(10, ps), "other lane touched"
    assert int(cache["lengths"][0]) == 0


# ---------------------------------------------------------------------------
# 2) pool alloc/free invariants (property test)
# ---------------------------------------------------------------------------

@settings(max_examples=30)
@given(st.lists(st.integers(0, 999), min_size=1, max_size=80))
def test_kv_pool_invariants(ops_seq):
    """Random alloc/free interleavings never leak, double-grant, or
    mis-count: every page is either free or owned by exactly one owner, and
    conservation holds after every operation."""
    N = 13
    pool = KVPool(num_pages=N, page_size=4)
    owners = []
    next_uid = 0
    for op in ops_seq:
        if op % 3 == 0 and owners:              # free a random owner
            uid = owners.pop(op % len(owners))
            freed = pool.free(uid)
            assert freed >= 0
            with pytest.raises(KeyError):       # double free always raises
                pool.free(uid)
        else:                                    # alloc 0..5 pages
            n = op % 6
            free_before = pool.free_pages
            got = pool.alloc(n, owner=next_uid)
            if n > free_before:
                assert got is None, "alloc must be all-or-nothing"
            else:
                assert got is not None and len(got) == n
                if next_uid not in owners:
                    owners.append(next_uid)
                next_uid += 1
        # conservation + exclusivity after EVERY op
        all_owned = [p for uid in pool.owners() for p in pool.owned(uid)]
        assert len(all_owned) == len(set(all_owned)), "page double-granted"
        assert all(1 <= p <= N for p in all_owned), "page id out of range"
        assert pool.free_pages + len(all_owned) == N, "pages leaked"
        assert pool.peak_used >= pool.used_pages


def test_kv_pool_watermark_and_frag():
    pool = KVPool(num_pages=10, page_size=8)
    pool.alloc(4, owner=1)
    pool.alloc(3, owner=2)
    assert pool.peak_used == 7
    pool.free(1)
    assert pool.free_pages == 7 and pool.peak_used == 7
    assert not pool.can_alloc(8)
    assert pool.can_alloc(7) and not pool.can_alloc(7, watermark=1)
    u = pool.utilization(live_tokens=20)        # 3 pages * 8 slots cover 20
    assert u["used_pages"] == 3
    assert u["internal_fragmentation"] == pytest.approx(1 - 20 / 24)


# ---------------------------------------------------------------------------
# 3) engine over the paged pool: lossless, even under preemption
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def backbone():
    cfg = tiny_cfg("vicuna-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        Tp = int(rng.choice([6, 9, 12]))
        mn = int(rng.choice([6, 10, 16]))
        p = np.asarray(jax.random.randint(jax.random.PRNGKey(100 + i), (Tp,),
                                          2, cfg.vocab_size), np.int32)
        reqs.append(Request(uid=i, prompt=p, max_new=mn))
    return reqs


def _ar_reference(model, params, req, eos=1):
    r = spec.ar_generate(model, params, jnp.asarray(req.prompt)[None, :],
                         req.max_new)
    gen = np.asarray(r.tokens[0, len(req.prompt):int(r.lengths[0])]).tolist()
    out = []
    for t in gen[:req.max_new]:
        out.append(int(t))
        if t == eos:
            break
    return out


@pytest.mark.parametrize("kv_pages,expect_preempt", [(40, False), (14, True)])
def test_engine_paged_lossless(backbone, kv_pages, expect_preempt):
    """Paged continuous serving emits EXACTLY the per-request greedy AR
    stream — with an ample pool, and with a pool so tight that lanes are
    preempted mid-decode and replayed."""
    cfg, model, params = backbone
    reqs = _requests(cfg, 7)
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    eng = ServingEngine(model, params, state, scheduler="continuous",
                        num_slots=3, max_new=16, cache_len=40,
                        kv_pages=kv_pages, kv_page_size=4)
    for r in reqs:
        eng.submit(r)
    outs = eng.run(max_steps=1000)
    assert len(outs) == len(reqs)
    assert not eng.busy
    by_uid = {o.uid: o for o in outs}
    for req in reqs:
        ref = _ar_reference(model, params, req)
        got = by_uid[req.uid].gen_tokens.tolist()
        assert got == ref, f"uid {req.uid}: {got} != AR {ref}"
        np.testing.assert_array_equal(
            by_uid[req.uid].tokens[:len(req.prompt)], req.prompt)
    kv = eng.kv_stats()
    if expect_preempt:
        assert kv["preemptions"] > 0, "tight pool should force preemption"
    assert kv["used_pages"] == 0, "retirement must free every page"
    assert kv["peak_used_pages"] <= kv_pages


def test_engine_paged_rejects_bad_config(backbone):
    cfg, model, params = backbone
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    with pytest.raises(ValueError):              # sync scheduler has no pool
        ServingEngine(model, params, state, scheduler="sync", kv_pages=8)
    with pytest.raises(ValueError):              # one request must fit
        ServingEngine(model, params, state, scheduler="continuous",
                      cache_len=40, kv_pages=2, kv_page_size=4)


# ---------------------------------------------------------------------------
# 4) equal memory -> strictly more admitted concurrency than contiguous
# ---------------------------------------------------------------------------

def test_paged_admits_more_concurrent_at_equal_memory(backbone):
    """Token-memory budget of 80 slots: contiguous worst-case reservation
    fits 2 lanes of 40; the paged pool (20 pages x 4) runs 6 lanes and must
    keep strictly more than 2 requests live at once — with zero output
    divergence."""
    cfg, model, params = backbone
    reqs = [Request(uid=i, prompt=np.asarray(
        jax.random.randint(jax.random.PRNGKey(200 + i), (6,), 2,
                           cfg.vocab_size), np.int32), max_new=4)
            for i in range(6)]

    def run(**kw):
        state = online.init_trainer(model, jax.random.PRNGKey(3))
        eng = ServingEngine(model, params, state, scheduler="continuous",
                            max_new=4, cache_len=40, **kw)
        for r in reqs:
            eng.submit(r)
        outs = eng.run(max_steps=1000)
        assert len(outs) == len(reqs)
        return eng, {o.uid: o.gen_tokens.tolist() for o in outs}

    eng_c, out_c = run(num_slots=2)                       # 2 x 40 = 80 slots
    eng_p, out_p = run(num_slots=6, kv_pages=20, kv_page_size=4)   # 80 slots
    assert out_c == out_p, "paged output diverged from contiguous"
    assert eng_c.stats["peak_live_slots"] <= 2
    assert eng_p.stats["peak_live_slots"] > 2, (
        "paged pool should admit more concurrent requests than contiguous "
        "worst-case reservation at equal memory")
    # more lanes live at once -> the same work takes fewer engine ticks
    assert eng_p.stats["blocks"] >= eng_c.stats["blocks"]
