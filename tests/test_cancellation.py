"""Boundary-only cancellation: queued, mid-chunked-prefill, mid-decode,
and prefix-shared requests all retire at the next superstep boundary,
untouched lanes stay bit-identical, the paged pool drains to baseline
(refcounted frees included), and the zero-host-sync contract survives
(cancellation adds no device_get: host_syncs == dispatches)."""
import jax
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core import online
from repro.models.model import build_model
from repro.serving import Request, ServingEngine

N_PAGES = 32


@pytest.fixture(scope="module")
def backbone():
    cfg = tiny_cfg("vicuna-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reqs(cfg, n, seed=0, max_new=10, plen=12):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(2, cfg.vocab_size, plen,
                                        dtype=np.int64).astype(np.int32),
                    max_new=max_new) for i in range(n)]


def _engine(model, params, **kw):
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    kw.setdefault("scheduler", "continuous")
    kw.setdefault("num_slots", 3)
    kw.setdefault("max_new", 16)
    kw.setdefault("buckets", (16,))
    return ServingEngine(model, params, state, **kw)


def _assert_clean(eng):
    """Post-drain invariants: no live lanes, pool at baseline, telemetry
    contract intact."""
    assert not eng.busy
    assert all(s is None for s in eng._slots)
    d = eng.dispatch_stats()
    assert d["host_syncs"] == d["dispatches"]
    if eng.kv_pages:
        kv = eng.kv_stats()
        assert kv["used_pages"] == 0
        assert kv["free_pages"] + kv["cached_pages"] == eng.kv_pages


def test_cancel_queued_never_runs(backbone):
    cfg, model, params = backbone
    eng = _engine(model, params, num_slots=2)
    hs = [eng.submit_request(r) for r in _reqs(cfg, 5, seed=1)]
    hs[4].cancel()                       # still queued (2 slots, 5 reqs)
    outs = eng.run(500)
    assert hs[4].outcome == "cancelled"
    assert hs[4].tokens() == []          # never admitted, never decoded
    assert {c.uid for c in outs} == {0, 1, 2, 3}
    assert eng.stats["cancelled"] == 1 and eng.stats["requests"] == 4
    _assert_clean(eng)


def test_cancel_mid_decode_keeps_other_lanes_bit_identical(backbone):
    cfg, model, params = backbone
    reqs = _reqs(cfg, 4, seed=2, max_new=16)

    ref = _engine(model, params)         # no-cancel reference streams
    for r in reqs:
        ref.submit_request(r)
    ref_outs = {c.uid: c.gen_tokens.tolist() for c in ref.run(500)}

    eng = _engine(model, params)
    hs = [eng.submit_request(r) for r in reqs]
    outs = list(eng.step())              # first superstep: lanes live
    hs[1].cancel()                       # honoured at the NEXT boundary
    outs += eng.run(500)
    assert hs[1].outcome == "cancelled"
    got1 = hs[1].tokens()
    assert got1 == ref_outs[1][:len(got1)]   # committed prefix preserved
    assert len(got1) < len(ref_outs[1])      # and generation stopped early
    for c in outs:                       # untouched lanes: bit-identical
        assert c.gen_tokens.tolist() == ref_outs[c.uid], f"uid {c.uid}"
    assert {c.uid for c in outs} == {0, 2, 3}
    _assert_clean(eng)


def test_cancel_mid_chunked_prefill(backbone):
    cfg, model, params = backbone
    eng = _engine(model, params, kv_pages=N_PAGES, kv_page_size=8,
                  prefill_chunk=8, num_slots=3)
    reqs = _reqs(cfg, 3, seed=3, plen=24, max_new=8)   # 3 chunks each
    hs = [eng.submit_request(r) for r in reqs]
    eng.step()                           # admit; prefill still chunking
    mid = {s.uid for s in eng._slots
           if s is not None and s.pf_pos is not None}
    assert mid, "no lane was mid-chunked-prefill after one tick"
    victim = hs[min(mid)]
    victim.cancel()
    eng.run(500)
    assert victim.outcome == "cancelled"
    done = [h for h in hs if h is not victim]
    assert all(h.outcome == "completed" for h in done)
    _assert_clean(eng)


def test_cancel_prefix_shared_decrefs_not_frees(backbone):
    cfg, model, params = backbone
    eng = _engine(model, params, kv_pages=N_PAGES, kv_page_size=8,
                  prefix_cache=True, prefill_chunk=8, num_slots=4)
    rng = np.random.default_rng(7)
    shared = rng.integers(2, cfg.vocab_size, 16,
                          dtype=np.int64).astype(np.int32)
    hs = [eng.submit_request(Request(uid=0, prompt=shared.copy(),
                                     max_new=12))]
    for _ in range(3):                   # publish uid 0's pages first so
        eng.step()                       # later arrivals can splice them
    hs += [eng.submit_request(Request(uid=i, prompt=shared.copy(),
                                      max_new=12)) for i in range(1, 4)]
    eng.step()
    assert eng.kv_stats()["prefix_hits"] >= 1   # sharing actually happened
    hs[1].cancel()
    hs[2].cancel()
    eng.run(500)
    assert hs[1].outcome == "cancelled" and hs[2].outcome == "cancelled"
    assert hs[0].outcome == "completed" and hs[3].outcome == "completed"
    # survivors decode the same stream sharing or not: greedy + same prefix
    assert hs[0].tokens() == hs[3].tokens()
    _assert_clean(eng)


def test_cancelled_then_resubmitted_prompt_hits_prefix_cache(backbone):
    cfg, model, params = backbone
    eng = _engine(model, params, kv_pages=N_PAGES, kv_page_size=8,
                  prefix_cache=True, prefill_chunk=8, num_slots=2)
    rng = np.random.default_rng(9)
    prompt = rng.integers(2, cfg.vocab_size, 16,
                          dtype=np.int64).astype(np.int32)
    h1 = eng.submit_request(Request(uid=0, prompt=prompt.copy(), max_new=16))
    # let prefill finish and publish pages into the prefix index, then
    # cancel mid-decode: the pages drop to refcount 0 but stay CACHED
    for _ in range(3):
        eng.step()
    h1.cancel()
    eng.run(500)
    assert h1.outcome == "cancelled"
    kv = eng.kv_stats()
    assert kv["cached_pages"] > 0        # cancel decref'd, didn't destroy
    hits0 = kv["prefix_hits"]
    h2 = eng.submit_request(Request(uid=1, prompt=prompt.copy(), max_new=16))
    outs = eng.run(500)
    assert h2.outcome == "completed"
    assert eng.kv_stats()["prefix_hits"] > hits0   # resubmit spliced cache
    # and the rerun stream extends the cancelled one's committed prefix
    assert h2.tokens()[:len(h1.tokens())] == h1.tokens()
    assert outs[-1].gen_tokens.tolist() == h2.tokens()
    _assert_clean(eng)


def test_cancel_is_idempotent_and_late_cancel_is_noop(backbone):
    cfg, model, params = backbone
    eng = _engine(model, params)
    [h] = [eng.submit_request(r) for r in _reqs(cfg, 1, seed=5, max_new=4)]
    assert h.cancel() and h.cancel()     # double-request: still one cancel
    eng.run(500)
    assert h.outcome == "cancelled"
    assert eng.stats["cancelled"] == 1   # counted once
    assert h.cancel() is False           # after the fact: nothing to do
    _assert_clean(eng)
