"""Fused superstep (`spec_superstep`): running S blocks in one device
dispatch must be BIT-IDENTICAL to S per-block ticks — (1) at the spec level
against a python reference replicating the host commit loop (greedy and
rejection-sampled, contiguous and paged caches), (2) at the engine level
across sync_every ∈ {1, 2, 8} and arrival orders, (3) the engine's host-sync
count actually drops with sync_every, (4) latency tracking is bounded by the
rolling window, and (5) paged page growth is capped by a lane's remaining
generation budget."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core import lora, online, spec
from repro.models.model import build_model
import repro.models.transformer as tfm
from repro.serving import Request, ServingEngine
from repro.serving.kv_pool import KVPool, pages_for

EOS = 1


@pytest.fixture(scope="module")
def backbone():
    cfg = tiny_cfg("vicuna-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    return cfg, model, params, dvi


def _blockstep_reference(model, params, dvi, pending, cache, steps, budget,
                         temperature=0.0, key=None):
    """The per-block host loop the engine used to run, verbatim: python-side
    commit with budget capping and stop-after-EOS, lanes masked done."""
    B = pending.shape[0]
    key = key if key is not None else jax.random.PRNGKey(0)
    done = np.zeros((B,), bool)
    gens = [[] for _ in range(B)]
    blocks = np.zeros((B,), np.int64)
    committed = np.zeros((B,), np.int64)
    accepted = np.zeros((B,), np.int64)

    @jax.jit
    def one_block(pending, cache, done, key):
        return spec.spec_block_step(model, params, dvi, pending, cache,
                                    done=done, temperature=temperature,
                                    key=key)

    for _ in range(steps):
        if done.all():
            break
        blk = one_block(pending, cache, jnp.asarray(done), key)
        pending, cache, key = blk.pending, blk.cache, blk.key
        acc = np.asarray(blk.accept)
        cv = np.asarray(blk.commit_vec)
        m = np.asarray(blk.m)
        for b in range(B):
            if done[b]:
                continue
            blocks[b] += 1
            committed[b] += acc[b]
            accepted[b] += m[b]
            for t in cv[b, :acc[b]]:
                if len(gens[b]) >= budget[b]:
                    break
                gens[b].append(int(t))
                if int(t) == EOS:
                    break
            if gens[b] and (gens[b][-1] == EOS or len(gens[b]) >= budget[b]):
                done[b] = True
    return gens, np.asarray(pending), done, blocks, committed, accepted


def _prefill_contiguous(model, prompts, params):
    _, cache, _ = model.prefill(params, prompts[:, :-1], max_len=96)
    return cache, prompts[:, -1]


def _prefill_paged(cfg, model, params, prompts, ps=4, mps=24):
    B, Tp = prompts.shape
    K = cfg.dvi.k_spec
    pool = KVPool(num_pages=B * mps, page_size=ps)
    cache = model.init_paged_cache(B, pool.num_pages, ps, mps)
    for b in range(B):
        need = pages_for(Tp - 1 + 10 * (K + 1), ps)   # covers the test run
        row = np.full(mps, -1, np.int32)
        row[:need] = pool.alloc(need, owner=b)
        cache = tfm.map_slot_pages(cache, jnp.int32(b), jnp.asarray(row))
        _, pc, _ = model.prefill(params, prompts[b:b + 1, :-1],
                                 max_len=Tp - 1)
        cache = tfm.insert_slot(cfg, cache, pc, jnp.int32(b))
    return cache, prompts[:, -1]


@pytest.mark.parametrize("steps", [1, 2, 8])
@pytest.mark.parametrize("temperature", [0.0, 0.7])
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_superstep_matches_blockstep_loop(backbone, steps, temperature,
                                          layout):
    cfg, model, params, dvi = backbone
    B, Tp = 3, 8
    prompts = jax.random.randint(jax.random.PRNGKey(7), (B, Tp), 2,
                                 cfg.vocab_size)
    budget = np.array([4, 9, 30], np.int32)          # one lane exhausts early
    key = jax.random.PRNGKey(99)

    if layout == "paged":
        cache, pending = _prefill_paged(cfg, model, params, prompts)
        rcache, rpending = _prefill_paged(cfg, model, params, prompts)
    else:
        cache, pending = _prefill_contiguous(model, prompts, params)
        rcache, rpending = _prefill_contiguous(model, prompts, params)

    res = spec.spec_superstep(model, params, dvi, pending, cache,
                              steps=steps, budget=jnp.asarray(budget),
                              eos_id=EOS, temperature=temperature, key=key)
    gens, rpend, rdone, rblocks, rcommitted, raccepted = _blockstep_reference(
        model, params, dvi, rpending, rcache, steps, budget,
        temperature=temperature, key=key)

    cnt = np.asarray(res.gen_count)
    buf = np.asarray(res.gen_buf)
    for b in range(B):
        assert buf[b, :cnt[b]].tolist() == gens[b], f"lane {b} stream"
    np.testing.assert_array_equal(np.asarray(res.done), rdone)
    np.testing.assert_array_equal(np.asarray(res.lane_blocks), rblocks)
    np.testing.assert_array_equal(np.asarray(res.lane_committed), rcommitted)
    np.testing.assert_array_equal(np.asarray(res.lane_accepted), raccepted)
    np.testing.assert_array_equal(np.asarray(res.pending), rpend)
    np.testing.assert_array_equal(np.asarray(res.cache["lengths"]),
                                  np.asarray(rcache["lengths"])
                                  + rcommitted.astype(np.int32))


def test_superstep_chain_equals_one_superstep(backbone):
    """Two chained supersteps of 2 == one superstep of 4 (done/budget carry
    across the boundary exactly)."""
    cfg, model, params, dvi = backbone
    B, Tp = 2, 8
    prompts = jax.random.randint(jax.random.PRNGKey(3), (B, Tp), 2,
                                 cfg.vocab_size)
    budget = jnp.asarray(np.array([5, 30], np.int32))

    cache, pending = _prefill_contiguous(model, prompts, params)
    one = spec.spec_superstep(model, params, dvi, pending, cache, steps=4,
                              budget=budget, eos_id=EOS)

    cache, pending = _prefill_contiguous(model, prompts, params)
    a = spec.spec_superstep(model, params, dvi, pending, cache, steps=2,
                            budget=budget, eos_id=EOS)
    b = spec.spec_superstep(model, params, dvi, a.pending, a.cache, steps=2,
                            done=a.done, budget=budget - a.gen_count,
                            eos_id=EOS)
    for lane in range(B):
        s1 = np.asarray(one.gen_buf)[lane, :int(one.gen_count[lane])]
        s2 = np.concatenate([
            np.asarray(a.gen_buf)[lane, :int(a.gen_count[lane])],
            np.asarray(b.gen_buf)[lane, :int(b.gen_count[lane])]])
        np.testing.assert_array_equal(s1, s2)
    np.testing.assert_array_equal(np.asarray(one.done), np.asarray(b.done))


# ---------------------------------------------------------------------------
# engine level: streams identical across sync_every, syncs actually drop
# ---------------------------------------------------------------------------

def _requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        Tp = int(rng.choice([6, 9, 12]))
        mn = int(rng.choice([6, 10, 16]))
        p = np.asarray(jax.random.randint(jax.random.PRNGKey(100 + i), (Tp,),
                                          2, cfg.vocab_size), np.int32)
        reqs.append(Request(uid=i, prompt=p, max_new=mn))
    return reqs


def _serve(model, params, reqs, order, **kw):
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    eng = ServingEngine(model, params, state, scheduler="continuous",
                        max_new=16, **kw)
    for i in order:
        eng.submit(reqs[i])
    outs = eng.run(max_steps=2000)
    assert len(outs) == len(reqs)
    assert not eng.busy
    return eng, {o.uid: o.gen_tokens.tolist() for o in outs}


@pytest.mark.parametrize("order_seed", [0, 3])
def test_engine_streams_identical_across_sync_every(backbone, order_seed):
    cfg, model, params, _ = backbone
    reqs = _requests(cfg, 5)
    order = np.random.default_rng(order_seed).permutation(len(reqs))
    base = None
    for s in (1, 2, 8):
        eng, streams = _serve(model, params, reqs, order,
                              num_slots=2, sync_every=s)
        if base is None:
            base = streams
        else:
            assert streams == base, f"sync_every={s} diverged"
        assert eng.stats["host_syncs"] == eng.stats["dispatches"]


@pytest.mark.parametrize("kv_pages", [40, 16])
def test_engine_paged_streams_identical_across_sync_every(backbone, kv_pages):
    """Ample pool, and a pool tight enough to force preemption mid-run:
    the fused superstep must stay lossless in both regimes (admission
    provisions the full first-superstep horizon, growth covers the rest)."""
    cfg, model, params, _ = backbone
    reqs = _requests(cfg, 5, seed=2)
    order = range(len(reqs))
    base = None
    for s in (1, 8):
        eng, streams = _serve(model, params, reqs, order, num_slots=2,
                              cache_len=40, kv_pages=kv_pages, kv_page_size=4,
                              sync_every=s)
        if base is None:
            base = streams
        else:
            assert streams == base, f"paged sync_every={s} diverged"
        assert eng.kv_stats()["used_pages"] == 0


def test_engine_host_syncs_drop_with_sync_every(backbone):
    cfg, model, params, _ = backbone
    reqs = _requests(cfg, 4, seed=9)
    per = {}
    for s in (1, 8):
        eng, _ = _serve(model, params, reqs, range(len(reqs)),
                        num_slots=2, sync_every=s)
        d = eng.dispatch_stats()
        assert d["sync_every"] == s
        per[s] = d["host_syncs_per_100_blocks"]
        assert eng.stats["blocks"] > 0
    assert per[8] <= per[1] / 5, (
        f"sync_every=8 should cut host syncs >=5x: {per}")


def test_latency_rolling_window(backbone):
    cfg, model, params, _ = backbone
    reqs = _requests(cfg, 6, seed=4)
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    eng = ServingEngine(model, params, state, scheduler="continuous",
                        num_slots=2, max_new=6, latency_window=3)
    for r in reqs:
        eng.submit(r)
    outs = eng.run(max_steps=2000)
    assert len(outs) == len(reqs)
    assert len(eng.stats["latencies"]) == 3      # capped at the window
    lat = eng.latency_percentiles()
    assert lat["p95_s"] >= lat["p50_s"] > 0.0
    assert eng.stats["requests"] == len(reqs)    # counters keep the truth


def test_grow_pages_capped_by_remaining_budget(backbone):
    """A lane with 2 tokens of budget left must NOT be grown to the full
    sync_every-block horizon: peak pool usage stays near prompt + one
    block, far below prompt + sync_every*(K+1)."""
    cfg, model, params, _ = backbone
    K = cfg.dvi.k_spec
    Tp, ps = 8, 4
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(0), (Tp,), 2,
                                           cfg.vocab_size), np.int32)
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    eng = ServingEngine(model, params, state, scheduler="continuous",
                        num_slots=1, max_new=2, cache_len=40, sync_every=8,
                        kv_pages=64, kv_page_size=ps)
    eng.submit(Request(uid=0, prompt=prompt, max_new=2))
    outs = eng.run(max_steps=100)
    assert len(outs) == 1
    capped = pages_for(Tp - 1 + (2 + K) + 1, ps)          # budget-capped
    uncapped = pages_for(Tp - 1 + 8 * (K + 1) + 1, ps)    # full horizon
    peak = eng.kv_stats()["peak_used_pages"]
    assert peak <= capped, f"peak {peak} > budget-capped bound {capped}"
    assert peak < uncapped
