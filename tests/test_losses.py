"""Objective + schedule unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DVIConfig
from repro.core import losses as L
from repro.core import lora, schedule as S


def test_lambda_schedule_piecewise():
    dvi = DVIConfig(split_layer=1, warmup_steps=100, ramp_steps=200,
                    lambda_kl0=1.0, lambda_kl_min=0.1, lambda_pg_max=1.0)
    pg0, kl0 = S.lambda_schedule(0, dvi)
    assert float(pg0) == 0.0 and float(kl0) == 1.0
    pg_mid, kl_mid = S.lambda_schedule(200, dvi)
    assert abs(float(pg_mid) - 0.5) < 1e-6
    assert abs(float(kl_mid) - 0.55) < 1e-6
    pg_end, kl_end = S.lambda_schedule(10_000, dvi)
    assert float(pg_end) == 1.0 and abs(float(kl_end) - 0.1) < 1e-6


def test_beta_decays():
    dvi = DVIConfig(split_layer=1, beta0=0.3, beta_min=0.03,
                    beta_decay_steps=100)
    assert float(S.beta_schedule(0, dvi)) == pytest.approx(0.3)
    assert float(S.beta_schedule(10_000, dvi)) == pytest.approx(0.03, rel=1e-3)


def _setup(tiny_models):
    cfg, model, params = tiny_models("vicuna-7b")
    dvi_params = lora.init_draft_params(jax.random.PRNGKey(0), cfg)
    N, d = 32, cfg.d_model
    batch = {
        "h_k": jax.random.normal(jax.random.PRNGKey(1), (N, d)),
        "h_L": jax.random.normal(jax.random.PRNGKey(2), (N, d)),
        "action": jax.random.randint(jax.random.PRNGKey(3), (N,), 0,
                                     cfg.vocab_size),
        "reward": (jax.random.uniform(jax.random.PRNGKey(4), (N,)) > 0.5
                   ).astype(jnp.float32),
        "mask": jnp.ones((N,)),
    }
    return cfg, model, params, dvi_params, batch


@pytest.mark.parametrize("mode", ["full", "kl", "pg", "ce"])
def test_all_modes_finite_with_grads(tiny_models, mode):
    cfg, model, params, dvi_params, batch = _setup(tiny_models)
    def f(dp):
        return L.composite_loss(dp, model, params, batch, batch,
                                jnp.int32(500), jnp.float32(0.5), mode)[0]
    loss, grads = jax.value_and_grad(f)(dvi_params)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


def test_kl_at_init_equals_head_gap(tiny_models):
    """At init (B=0), the drafter IS the verifier head read at h_k, so
    KL(p_theta(h)||p_phi(h)) with h_k == h_L must be ~0 at tau=1."""
    cfg, model, params, dvi_params, batch = _setup(tiny_models)
    same = dict(batch, h_L=batch["h_k"])
    terms = L.loss_terms(model, params, dvi_params, same)
    assert float(terms["kl_1"]) < 1e-5


def test_one_kl_step_descends(tiny_models):
    cfg, model, params, dvi_params, batch = _setup(tiny_models)
    def f(dp):
        return L.composite_loss(dp, model, params, batch, None,
                                jnp.int32(0), jnp.float32(0.0), "kl")[0]
    l0, g = jax.value_and_grad(f)(dvi_params)
    dp2 = jax.tree.map(lambda p, gg: p - 0.1 * gg, dvi_params, g)
    l1 = f(dp2)
    assert float(l1) < float(l0)


def test_grads_only_on_lora(tiny_models):
    """The backbone never sees a gradient (the paper's cheap-training
    claim): grad of the composite loss wrt params is identically zero."""
    cfg, model, params, dvi_params, batch = _setup(tiny_models)
    def f(p):
        return L.composite_loss(dvi_params, model, p, batch, None,
                                jnp.int32(0), jnp.float32(0.0), "full")[0]
    # verifier logits do depend on params (frozen head) — but we treat
    # params as non-differentiated by construction: the update fn only
    # takes grad wrt dvi_params.  Check that dvi grads are nonzero while a
    # params grad taken wrt the same loss stays finite (sanity).
    g = jax.grad(lambda dp: f(params) * 0.0 + L.composite_loss(
        dp, model, params, batch, None, jnp.int32(0), jnp.float32(0.0),
        "full")[0])(dvi_params)
    assert any(float(jnp.abs(x).sum()) > 0 for x in jax.tree.leaves(g))


def test_dense_train_losses_runs(tiny_models):
    cfg, model, params = tiny_models("vicuna-7b")
    dvi_params = lora.init_draft_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    loss, metrics = L.dense_train_losses(model, params, dvi_params, toks,
                                         jnp.int32(0), jnp.float32(0.0))
    assert bool(jnp.isfinite(loss))
    assert 0.0 <= float(metrics["acc_rate"]) <= 1.0
