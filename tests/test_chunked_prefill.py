"""Chunked prefill: (1) a cache built chunk-by-chunk (``prefill`` of the
first chunk + ``prefill_chunk`` for the rest) decodes BIT-IDENTICALLY to
one-shot prefill — greedy and rejection-sampled, contiguous and paged,
across chunk sizes {whole-prompt, ragged last chunk, 1 token}; (2) the
continuous engine with ``prefill_chunk`` set emits exactly the one-shot
engine's streams for any chunk size, paged or not; (3) mid-prefill
preemption under a tight page pool stays lossless; (4) per-tick prefill
work is bounded by the chunk budget; (5) ``done``-masked lanes ride a
superstep with stateful-mixer state, cache length, and pending frozen —
the invariant that lets mid-prefill lanes coexist with decode supersteps
in one batch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core import lora, online, spec
from repro.models.model import build_model
import repro.models.transformer as tfm
from repro.serving import Request, ServingEngine
from repro.serving.kv_pool import pages_for


@pytest.fixture(scope="module")
def backbone():
    cfg = tiny_cfg("vicuna-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    return cfg, model, params, dvi


# ---------------------------------------------------------------------------
# 1) model-level: chunk-built cache == one-shot cache, by decoded stream
# ---------------------------------------------------------------------------

def _paged_scaffold(model, cap, page_size=4):
    """B=1 paged cache with lane 0 mapped over enough pages for `cap`."""
    mps = pages_for(cap, page_size)
    cache = model.init_paged_cache(1, mps, page_size, mps)
    row = np.arange(1, mps + 1, dtype=np.int32)      # page 0 is the null page
    return tfm.map_slot_pages(cache, jnp.int32(0), jnp.asarray(row))


def _build_chunked(model, params, prompt, chunk, paged, cap):
    """prefill(first chunk) into a scratch + insert_slot (partially-built
    source), then prefill_chunk for the rest — the engine's exact recipe."""
    cfg = model.cfg
    n = prompt.shape[1] - 1
    c1 = min(chunk, n)
    live = (_paged_scaffold(model, cap) if paged
            else model.init_cache(1, cap))
    _, scratch, _ = model.prefill(params, jnp.asarray(prompt[:, :c1]),
                                  max_len=c1)
    cache = tfm.insert_slot(cfg, live, scratch, jnp.int32(0))
    pos = c1
    while pos < n:
        take = min(chunk, n - pos)
        blk = np.zeros((1, chunk), np.int32)         # ragged chunk: padded,
        blk[0, :take] = prompt[0, pos:pos + take]    # committed via `take`
        _, cache = model.prefill_chunk(params, jnp.asarray(blk), cache,
                                       jnp.array([take], jnp.int32))
        pos += take
    return cache


@pytest.mark.parametrize("paged", [False, True])
@pytest.mark.parametrize("chunk", [1, 7, 24])        # 1-token / ragged / 1-chunk
def test_chunked_cache_streams_bit_identical(backbone, paged, chunk):
    cfg, model, params, dvi = backbone
    Tp, max_new = 25, 20
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (1, Tp), 2,
                                           cfg.vocab_size), np.int32)
    cap = Tp + max_new + cfg.dvi.k_spec + 2 + tfm.RING_SLACK

    def decode(cache, temp):
        res = spec.spec_superstep(
            model, params, dvi, jnp.asarray(prompt[:, -1]), cache, steps=30,
            budget=jnp.array([max_new], jnp.int32), temperature=temp,
            key=jax.random.PRNGKey(9), collect=False)
        return np.asarray(res.gen_buf[0, :int(res.gen_count[0])]).tolist()

    if paged:
        ref_cache = _paged_scaffold(model, cap)
        _, scratch, _ = model.prefill(params, jnp.asarray(prompt[:, :-1]),
                                      max_len=Tp - 1)
        ref_cache = tfm.insert_slot(cfg, ref_cache, scratch, jnp.int32(0))
    else:
        _, ref_cache, _ = model.prefill(params, jnp.asarray(prompt[:, :-1]),
                                        max_len=cap)
    chunked_cache = _build_chunked(model, params, prompt, chunk, paged, cap)
    for temp in (0.0, 0.8):                          # greedy AND sampled
        assert decode(ref_cache, temp) == decode(chunked_cache, temp), \
            f"paged={paged} chunk={chunk} temp={temp}"


# ---------------------------------------------------------------------------
# 2) engine-level: --prefill-chunk is invisible in the token streams
# ---------------------------------------------------------------------------

def _requests(cfg, n, seed=0, long_lens=(20, 33)):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        Tp = int(rng.choice([6] + list(long_lens)))
        mn = int(rng.choice([6, 10, 16]))
        p = np.asarray(jax.random.randint(jax.random.PRNGKey(100 + i), (Tp,),
                                          2, cfg.vocab_size), np.int32)
        reqs.append(Request(uid=i, prompt=p, max_new=mn))
    return reqs


def _run_engine(model, params, reqs, **kw):
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    eng = ServingEngine(model, params, state, scheduler="continuous",
                        num_slots=3, max_new=16, **kw)
    for r in reqs:
        eng.submit(r)
    outs = eng.run(max_steps=4000)
    assert len(outs) == len(reqs)
    assert not eng.busy
    return {o.uid: o.gen_tokens.tolist() for o in outs}, eng


@pytest.mark.parametrize("paged", [False, True])
def test_engine_chunked_streams_bit_identical(backbone, paged):
    cfg, model, params, _ = backbone
    reqs = _requests(cfg, 6)
    kw = dict(kv_pages=40, kv_page_size=4, cache_len=64) if paged else {}
    ref, _ = _run_engine(model, params, reqs, prefill_chunk=0, **kw)
    for chunk in (1, 6, 64):                         # 1-token/ragged/1-chunk
        got, eng = _run_engine(model, params, reqs, prefill_chunk=chunk,
                               sync_every=2, **kw)
        assert got == ref, f"paged={paged} chunk={chunk}"
        if chunk < 20:                               # long prompts chunked
            assert eng.stats["prefill_chunks"] > 0
        else:                                        # everything fit chunk 1
            assert eng.stats["prefill_chunks"] == 0


# ---------------------------------------------------------------------------
# 3) mid-prefill preemption under a tight pool is lossless
# ---------------------------------------------------------------------------

def test_engine_chunked_preemption_lossless(backbone):
    cfg, model, params, _ = backbone
    reqs = _requests(cfg, 7, seed=1, long_lens=(24, 33))
    ref, _ = _run_engine(model, params, reqs, prefill_chunk=0, kv_pages=60,
                         kv_page_size=4, cache_len=64)
    got, eng = _run_engine(model, params, reqs, prefill_chunk=5,
                           sync_every=2, kv_pages=16, kv_page_size=4,
                           cache_len=64)
    assert got == ref
    assert eng.stats["preemptions"] > 0, "tight pool should force preemption"
    kv = eng.kv_stats()
    assert kv["used_pages"] == 0, "retirement must free every page"


# ---------------------------------------------------------------------------
# 4) per-tick prefill work is bounded by the chunk budget
# ---------------------------------------------------------------------------

def test_per_tick_prefill_work_bounded(backbone):
    cfg, model, params, _ = backbone
    chunk, slots = 4, 3
    reqs = _requests(cfg, 6, seed=2, long_lens=(33,))
    got, eng = _run_engine(model, params, reqs, prefill_chunk=chunk,
                           sync_every=2)
    # the chunk budget contract: ONE chunk step per tick, each prefilling
    # lane advancing at most `chunk` tokens — so no tick ever does more
    # than num_slots * chunk tokens of prefill work, however long prompts get
    assert eng.stats["prefill_chunks"] > 0
    assert 0 < eng.stats["max_tick_prefill_tokens"] <= slots * chunk
    assert eng.stats["prefill_chunks"] <= len(eng.stats["tick_s"])
    # decode kept interleaving: supersteps outnumber pure-prefill ticks
    assert eng.stats["dispatches"] > 0
    # one-shot engine does no chunk work at all
    _, eng0 = _run_engine(model, params, reqs, prefill_chunk=0)
    assert eng0.stats["max_tick_prefill_tokens"] == 0
    assert eng0.stats["prefill_chunks"] == 0


# ---------------------------------------------------------------------------
# 5) done-masked lanes are FROZEN through a superstep (prefill-lane safety)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["vicuna-7b", "mamba2-370m",
                                  "recurrentgemma-9b"])
def test_done_lane_frozen_through_superstep(tiny_models, arch):
    """A done-masked lane's committed cache length, pending token, and
    stateful-mixer conv/state must come out of a superstep bit-identical —
    a mid-prefill lane rides along masked and then RESUMES from them."""
    cfg, model, params = tiny_models(arch)
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (2, 9), 2,
                                            cfg.vocab_size), np.int32)
    _, cache, _ = model.prefill(params, jnp.asarray(prompts[:, :-1]),
                                max_len=48)
    pending = jnp.asarray(prompts[:, -1])
    done = jnp.array([True, False])                  # lane 0 rides masked
    res = spec.spec_superstep(model, params, dvi, pending, cache, steps=3,
                              done=done, budget=jnp.array([8, 8], jnp.int32),
                              collect=False)
    assert int(res.gen_count[0]) == 0
    assert int(res.pending[0]) == int(pending[0])
    assert int(res.cache["lengths"][0]) == int(cache["lengths"][0])
    for name, seg_c in cache["segs"].items():
        for key in ("conv", "state"):
            if key not in seg_c:
                continue
            np.testing.assert_array_equal(
                np.asarray(seg_c[key][:, 0]),
                np.asarray(res.cache["segs"][name][key][:, 0]),
                err_msg=f"{arch} {name}.{key} drifted on a done lane")
    # the live lane did decode
    assert int(res.gen_count[1]) > 0


# ---------------------------------------------------------------------------
# 6) insert_slot accepts a partially-built (smaller-capacity) source
# ---------------------------------------------------------------------------

def test_insert_slot_partial_source(backbone):
    cfg, model, params, _ = backbone
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(4), (1, 8), 2,
                                           cfg.vocab_size), np.int32)
    live = model.init_cache(3, 40)
    _, scratch, _ = model.prefill(params, jnp.asarray(prompt), max_len=8)
    out = tfm.insert_slot(cfg, live, scratch, jnp.int32(1))
    assert int(out["lengths"][1]) == 8
    for name, seg_c in out["segs"].items():
        src_c = scratch["segs"][name]
        if "k" not in seg_c:
            continue
        C_src = src_c["k"].shape[2]
        np.testing.assert_array_equal(np.asarray(seg_c["k"][:, 1, :C_src]),
                                      np.asarray(src_c["k"][:, 0]))
        # beyond the partial source the lane stays inert
        assert (np.asarray(out["segs"][name]["pos"][1, C_src:]) == -1).all()
    # untouched lanes stay bit-identical
    for name, seg_c in out["segs"].items():
        np.testing.assert_array_equal(np.asarray(seg_c["k"][:, 0]),
                                      np.asarray(live["segs"][name]["k"][:, 0]))
