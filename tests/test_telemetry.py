"""Unified telemetry subsystem: the metrics registry round-trips through
Prometheus text, the lifecycle tracer emits schema-valid Chrome traces,
and — the hard contract — turning telemetry ON adds ZERO host syncs and
leaves committed token streams bit-identical (the in-graph histograms are
computed unconditionally, so telemetry on/off shares one compiled graph,
and every host-side observation rides the harvest's single device_get)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core import lora, online, spec
from repro.core import schedule as sched
from repro.models.model import build_model
from repro.serving import Request, ServingEngine
from repro.serving.telemetry import (
    Counter, Gauge, Histogram, MetricsRegistry, ServingTelemetry, Tracer,
    log_buckets, parse_prometheus_text, render_prometheus, snapshot_delta,
    validate_trace, LEGACY_STATS, DEQUE_STATS)

EOS = 1


@pytest.fixture(scope="module")
def backbone():
    cfg = tiny_cfg("vicuna-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n, seed=0, max_new=16):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        Tp = int(rng.choice([6, 9, 12]))
        p = np.asarray(jax.random.randint(jax.random.PRNGKey(100 + i), (Tp,),
                                          2, cfg.vocab_size), np.int32)
        reqs.append(Request(uid=i, prompt=p, max_new=max_new))
    return reqs


def _serve(model, params, reqs, **kw):
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    eng = ServingEngine(model, params, state, scheduler="continuous",
                        buckets=(16,), **kw)
    for r in reqs:
        eng.submit(r)
    outs = eng.run(max_steps=1000)
    return eng, outs


def _streams(outs):
    return {o.uid: o.gen_tokens.tolist() for o in outs}


# ---------------------------------------------------------------------------
# registry units
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    c = Counter("c", "help")
    c.inc()
    c.inc(3)
    assert c.value == 4
    c.reset()
    assert c.value == 0
    g = Gauge("g", "help")
    g.set(2.5)
    g.set_max(1.0)
    assert g.value == 2.5
    g.set_max(7.0)
    assert g.value == 7.0


def test_log_buckets():
    bs = log_buckets(1e-4, 64.0)
    assert bs == sorted(bs) and len(set(bs)) == len(bs)
    assert bs[0] == pytest.approx(1e-4) and bs[-1] >= 64.0
    with pytest.raises(ValueError):
        log_buckets(0.0, 1.0)
    with pytest.raises(ValueError):
        log_buckets(2.0, 1.0)


def test_histogram_observe_add_snapshot():
    h = Histogram("h", "", [1, 2, 4])
    h.observe(0.5)
    h.observe(2)          # le-style: lands in the bucket with bound 2
    h.observe(100)        # overflow -> +Inf slot
    h.add(3, 5)           # exact integer fold keeps sum exact
    s = h.to_snapshot()
    assert s["count"] == 8
    assert s["sum"] == 0.5 + 2 + 100 + 15
    assert s["buckets"][-1][0] == "+Inf"
    cums = [c for _, c in s["buckets"]]
    assert cums == sorted(cums) and cums[-1] == s["count"]
    with pytest.raises(ValueError):
        Histogram("bad", "", [2, 1])


def test_registry_duplicate_name_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError):
        reg.gauge("x")


def test_prometheus_round_trip_unit():
    reg = MetricsRegistry()
    reg.counter("a_total", "a counter").inc(3)
    reg.gauge("b_gauge", "a gauge").set(-1.25)
    h = reg.histogram("c_hist", "a histogram", [1, 2])
    h.observe(0.5)
    h.observe(9)
    snap = reg.snapshot()
    back = parse_prometheus_text(render_prometheus(snap))
    assert set(back) == set(snap)
    for name, m in snap.items():
        assert back[name]["type"] == m["type"]
        if m["type"] == "histogram":
            assert back[name]["count"] == m["count"]
            assert back[name]["sum"] == m["sum"]
            assert back[name]["buckets"] == [[b, c] for b, c in m["buckets"]]
        else:
            assert back[name]["value"] == m["value"]


def test_snapshot_delta():
    reg = MetricsRegistry()
    c = reg.counter("c_total")
    g = reg.gauge("g")
    h = reg.histogram("h", "", [1])
    c.inc(2)
    g.set(5)
    h.observe(0.5)
    prev = reg.snapshot()
    c.inc(3)
    g.set(1)
    h.observe(2)
    d = snapshot_delta(reg.snapshot(), prev)
    assert d["c_total"]["value"] == 3
    assert d["g"]["value"] == 1            # gauges keep the current value
    assert d["h"]["count"] == 1 and d["h"]["sum"] == 2
    assert d["h"]["buckets"] == [[1, 0], ["+Inf", 1]]


# ---------------------------------------------------------------------------
# tracer units
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self, t=100.0):
        self.t = t

    def __call__(self):
        return self.t


def test_tracer_fake_clock_deterministic():
    fc = FakeClock(100.0)
    tr = Tracer(clock=fc, limit=100)
    tr.span(0, "tick", 100.0, 100.25)
    fc.t = 101.0
    tr.instant(1, "hit")
    ev_span, ev_inst = tr.events[-2], tr.events[-1]
    assert ev_span["ts"] == 0.0 and ev_span["dur"] == pytest.approx(0.25e6)
    assert ev_inst["ts"] == pytest.approx(1e6)
    d = tr.to_dict()
    assert d["otherData"]["dropped_events"] == 0
    validate_trace(d)


def test_tracer_event_cap_drops_not_grows():
    tr = Tracer(clock=FakeClock(), limit=3)
    for i in range(10):
        tr.instant(0, f"i{i}", t=100.0 + i)
    assert len(tr.events) == 3
    assert tr.to_dict()["otherData"]["dropped_events"] == 8


def test_validate_trace_catches_violations():
    def tr(*events):
        return {"traceEvents": list(events)}

    x = {"name": "a", "ph": "X", "pid": 0, "tid": 0, "ts": 0.0, "dur": 10.0}
    y = {"name": "b", "ph": "X", "pid": 0, "tid": 0, "ts": 5.0, "dur": 10.0}
    with pytest.raises(ValueError, match="half-overlap"):
        validate_trace(tr(x, y))
    validate_trace(tr(x, dict(y, ts=2.0, dur=3.0)))      # nested: fine
    validate_trace(tr(x, dict(y, ts=10.0)))              # disjoint: fine
    with pytest.raises(ValueError, match="missing"):
        validate_trace(tr({"name": "a", "ph": "X", "pid": 0}))
    b = {"name": "req", "ph": "b", "pid": 0, "tid": 0, "cat": "r", "id": 7,
         "ts": 0.0}
    e = dict(b, ph="e", ts=4.0)
    validate_trace(tr(b, e))
    with pytest.raises(ValueError, match="unclosed"):
        validate_trace(tr(b))
    with pytest.raises(ValueError, match="without begin"):
        validate_trace(tr(e))
    with pytest.raises(ValueError, match="ends before"):
        validate_trace(tr(dict(b, ts=9.0), e))


# ---------------------------------------------------------------------------
# schedule mirror + stats facade
# ---------------------------------------------------------------------------

def test_phase_info_matches_jnp_schedules():
    dvi = tiny_cfg("vicuna-7b").dvi
    probes = [0, 1, dvi.warmup_steps - 1, dvi.warmup_steps,
              dvi.warmup_steps + max(dvi.ramp_steps // 2, 1),
              dvi.warmup_steps + dvi.ramp_steps,
              dvi.warmup_steps + dvi.ramp_steps + 100, 10_000]
    for t in probes:
        info = sched.phase_info(t, dvi)
        lam_pg, lam_kl = sched.lambda_schedule(jnp.int32(t), dvi)
        assert info["lambda_pg"] == pytest.approx(float(lam_pg), abs=1e-6)
        assert info["lambda_kl"] == pytest.approx(float(lam_kl), abs=1e-6)
        assert info["beta"] == pytest.approx(
            float(sched.beta_schedule(jnp.int32(t), dvi)), rel=1e-5)
        assert info["gate"] == pytest.approx(
            float(sched.policy_gate(jnp.int32(t), dvi)), abs=1e-6)
        assert info["phase"] in (0, 1, 2)
        assert (info["phase"] == 0) == (t < dvi.warmup_steps)
        assert (info["phase"] == 2) == (t >= dvi.warmup_steps
                                        + dvi.ramp_steps)


def test_stats_view_facade():
    telem = ServingTelemetry(num_slots=2, k_max=4, latency_window=16,
                             clock=FakeClock())
    st = telem.stats
    st["requests"] += 2                       # read-modify-write idiom
    st["sync_wait_s"] += 0.5
    assert st["requests"] == 2
    assert st["sync_wait_s"] == 0.5
    st["latencies"].append(1.0)               # deque entries are live objects
    assert list(st["latencies"]) == [1.0]
    with pytest.raises(KeyError):
        st["made_up_key"] = 1
    assert set(LEGACY_STATS) | set(DEQUE_STATS) == set(st)
    st.reset()
    assert st["requests"] == 0 and len(st["latencies"]) == 0
    # the registry exposes exactly the keys LEGACY_STATS declares
    for name, _, _ in LEGACY_STATS.values():
        assert name in telem.registry.names()


# ---------------------------------------------------------------------------
# superstep in-graph histograms (greedy + rejection-sampled)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_superstep_hists_reconcile(backbone, temperature):
    """The in-graph per-block histograms are EXACT decompositions of the
    flat counters — greedy and rejection-sampled alike."""
    cfg, model, params = backbone
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    B, Tp = 3, 8
    prompts = jax.random.randint(jax.random.PRNGKey(7), (B, Tp), 2,
                                 cfg.vocab_size)
    _, cache, _ = model.prefill(params, prompts[:, :-1], max_len=96)
    res = spec.spec_superstep(model, params, dvi, prompts[:, -1], cache,
                              steps=6, budget=jnp.array([4, 9, 30]),
                              eos_id=EOS, temperature=temperature,
                              key=jax.random.PRNGKey(99))
    K = cfg.dvi.k_spec
    ah = np.asarray(res.accept_hist)
    dh = np.asarray(res.depth_hist)
    assert ah.shape == dh.shape == (K + 1,)
    blocks = int(np.asarray(res.lane_blocks).sum())
    assert ah.sum() == blocks == dh.sum()
    assert (ah * np.arange(K + 1)).sum() == \
        int(np.asarray(res.lane_accepted).sum())
    assert (dh * np.arange(K + 1)).sum() == \
        int(np.asarray(res.lane_drafted).sum())


# ---------------------------------------------------------------------------
# engine: zero-host-sync bit-identity, trace validity, reconciliation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_pages,sync_every", [(0, 1), (0, 8), (40, 8)])
def test_telemetry_on_off_bit_identity(backbone, kv_pages, sync_every):
    """Telemetry ON vs OFF: identical committed streams, identical
    host_syncs/dispatches (the tracer rides the existing harvest), and
    the per-block histograms reconcile exactly with the flat counters."""
    cfg, model, params = backbone
    reqs = _requests(cfg, 5, seed=2, max_new=12)
    kw = dict(num_slots=3, max_new=12, sync_every=sync_every, learn=False)
    if kv_pages:
        kw.update(kv_pages=kv_pages, kv_page_size=4, cache_len=40)
    off_eng, off = _serve(model, params, reqs, telemetry=False, **kw)
    on_eng, on = _serve(model, params, reqs, telemetry=True, **kw)
    assert _streams(on) == _streams(off)
    for k in ("host_syncs", "dispatches", "blocks", "steps", "committed",
              "accepted", "drafted", "requests"):
        assert on_eng.stats[k] == off_eng.stats[k], k
    # one sync per superstep dispatch — telemetry added none
    assert on_eng.stats["host_syncs"] == on_eng.stats["dispatches"]

    snap = on_eng.metrics_snapshot()
    ah = snap["dvi_serving_block_accepted_drafts"]
    dh = snap["dvi_serving_block_depth"]
    assert ah["count"] == on_eng.stats["blocks"] == dh["count"]
    assert ah["sum"] == on_eng.stats["accepted"]
    assert dh["sum"] == on_eng.stats["drafted"]
    assert snap["dvi_serving_request_latency_seconds"]["count"] == len(reqs)

    trace = on_eng.trace_dict()
    validate_trace(trace)
    assert off_eng.trace_dict() is None
    with pytest.raises(ValueError):
        off_eng.write_trace("/dev/null")


def test_trace_valid_with_preemption_replay(backbone, tmp_path):
    """A pool tight enough to force preemption/replay still yields a
    schema-valid trace covering every request lifecycle, with the
    preempt instants and replayed queued phases recorded."""
    cfg, model, params = backbone
    reqs = _requests(cfg, 7, seed=0, max_new=16)
    eng, outs = _serve(model, params, reqs, num_slots=3, max_new=16,
                       cache_len=40, kv_pages=14, kv_page_size=4,
                       sync_every=2, learn=False, telemetry=True)
    assert len(outs) == len(reqs)
    assert eng.stats["preemptions"] > 0, "tight pool should force preemption"
    trace = eng.trace_dict()
    tracks = validate_trace(trace)            # nesting + async pairing
    evs = trace["traceEvents"]
    # every request's lifecycle opens and closes
    begins = [e for e in evs if e["ph"] == "b" and e["name"] == "request"]
    ends = [e for e in evs if e["ph"] == "e" and e["name"] == "request"]
    assert {e["id"] for e in begins} == {r.uid for r in reqs}
    assert len(begins) == len(ends) == len(reqs)
    names = {e["name"] for e in evs}
    assert {"queued", "prefill", "decode", "superstep", "tick",
            "sync_wait", "preempt"} <= names
    replayed = [e for e in evs if e["ph"] == "b" and e["name"] == "queued"
                and e.get("args", {}).get("replay")]
    assert replayed, "preempted lanes must re-enter a queued phase"
    # lane tracks and the engine track both carry spans
    lane_spans = [e for t in range(eng.num_slots) for e in tracks.get(t, [])
                  if e["ph"] == "X"]
    assert lane_spans
    assert any(e["ph"] == "X" for e in tracks[eng.telem.tid_engine])

    out = tmp_path / "trace.json"
    eng.write_trace(str(out))
    validate_trace(json.loads(out.read_text()))
    mpath = tmp_path / "metrics.prom"
    eng.write_metrics(str(mpath))
    back = parse_prometheus_text(mpath.read_text())
    assert back["dvi_serving_preemptions_total"]["value"] == \
        eng.stats["preemptions"]


def test_train_telemetry_and_prometheus_exposure(backbone):
    """A learning run must surface all three DVI loss components and the
    acceptance EMA around updates — in train_telemetry(), in the bounded
    history, and in the Prometheus rendering."""
    cfg, model, params = backbone
    reqs = _requests(cfg, 6, seed=4, max_new=12)
    eng, outs = _serve(model, params, reqs, num_slots=3, max_new=12,
                       sync_every=2, learn=True, update_every=2,
                       telemetry=True)
    assert len(outs) == len(reqs)
    tt = eng.train_telemetry()
    assert tt["updates"] > 0
    assert tt["step"] == tt["updates"]
    assert tt["phase_name"] in ("warmup", "ramp", "rl")
    for k in ("loss", "loss_kl", "loss_ce", "loss_pg", "lambda_pg",
              "lambda_kl", "beta", "acceptance_batch",
              "acceptance_ema_before", "acceptance_ema_after"):
        assert np.isfinite(tt[k]), k
    assert tt["history"], "per-update history must accumulate"
    rec = tt["history"][-1]
    assert rec["step"] >= 1 and rec["span_s"] >= 0.0
    assert {"loss", "loss_kl", "loss_ce", "loss_pg", "ema_before",
            "ema_after", "phase"} <= set(rec)

    prom = eng.render_prometheus()
    for name in ("dvi_train_loss_kl", "dvi_train_loss_ce",
                 "dvi_train_loss_pg", "dvi_train_acceptance_ema_after",
                 "dvi_serving_block_accepted_drafts_bucket",
                 "dvi_serving_block_depth_bucket"):
        assert name in prom, name
    back = parse_prometheus_text(prom)
    assert back["dvi_train_updates_total"]["value"] == tt["updates"]
    assert back["dvi_train_loss_kl"]["value"] == \
        pytest.approx(tt["loss_kl"], rel=1e-6)

    # reset clears the registry, the deques, and the history
    eng.reset_stats()
    assert eng.stats["requests"] == 0
    assert eng.metrics_snapshot()["dvi_serving_blocks_total"]["value"] == 0
    assert eng.train_telemetry()["history"] == []


def test_frozen_clock_all_durations_zero(backbone):
    """With a frozen injected clock every recorded duration is EXACTLY
    zero — any residual time.time()/perf_counter() in a duration path
    would leak nonzero wall time into latencies/ticks/sync waits."""
    cfg, model, params = backbone
    reqs = _requests(cfg, 4, seed=6, max_new=8)
    eng, outs = _serve(model, params, reqs, num_slots=2, max_new=8,
                       sync_every=2, learn=False, clock=FakeClock(7.0))
    assert len(outs) == len(reqs)
    assert all(v == 0.0 for v in eng.stats["latencies"])
    assert all(v == 0.0 for v in eng.stats["tick_s"])
    assert eng.stats["sync_wait_s"] == 0.0
    assert all(o.latency_s == 0.0 for o in outs)
    lat = eng.latency_percentiles()
    assert lat["count"] == len(reqs) and lat["p50_s"] == 0.0
    snap = eng.metrics_snapshot()
    assert snap["dvi_serving_request_latency_seconds"]["sum"] == 0.0


def test_empty_percentiles_have_count_key(backbone):
    cfg, model, params = backbone
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    eng = ServingEngine(model, params, state, scheduler="continuous",
                        num_slots=2, buckets=(16,))
    lat = eng.latency_percentiles()
    tick = eng.tick_percentiles()
    assert lat == {"p50_s": 0.0, "p95_s": 0.0, "mean_s": 0.0, "count": 0}
    assert tick["count"] == 0 and tick["p50_s"] == 0.0 \
        and tick["max_s"] == 0.0
