import jax
import jax.numpy as jnp
import pytest

try:                                  # property tests use hypothesis when
    import hypothesis  # noqa: F401  # available; else a deterministic shim
except ModuleNotFoundError:
    import _hyp_fallback
    _hyp_fallback.install()

from repro.configs import ALL_ARCHS, get_config
from repro.models.model import build_model

ARCHS = ALL_ARCHS  # includes vicuna-7b (the paper's backbone) + 10 assigned


def tiny_cfg(name: str):
    """fp32 reduced config (exact argmax comparisons need fp32)."""
    return get_config(name, tiny=True).replace(dtype="float32")


def make_aux(cfg, B, seed=3):
    aux = {}
    if cfg.vision is not None:
        aux["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed), (B, cfg.vision.num_patches, cfg.vision.d_embed))
    if cfg.encoder is not None:
        aux["frame_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (B, cfg.encoder.num_frames, cfg.encoder.d_model or cfg.d_model))
    return aux or None


@pytest.fixture(autouse=True, scope="module")
def _bounded_compile_cache():
    """Drop XLA's in-process executable caches between test modules.

    The caches grow without bound over the full suite (every module builds
    fresh Model closures, so nothing is ever evicted); on single-core CPU
    runners the accumulated compiler state deterministically segfaults
    LLVM mid-compile ~190 tests in.  Modules don't share compiled
    functions (model fixtures are module-scoped), so clearing between
    modules only re-pays compiles the next module would do anyway."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def tiny_models():
    """Cache of (cfg, model, params) per arch — init once per session."""
    cache = {}

    def get(name):
        if name not in cache:
            cfg = tiny_cfg(name)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[name] = (cfg, model, params)
        return cache[name]

    return get
