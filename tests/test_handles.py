"""RequestHandle + TenantQueue semantics — pure host-side, no device work.

The handle is the thread boundary the HTTP front-end stands on: feed is
monotone on the authoritative token total (replays never re-deliver),
finish is idempotent, deltas/result wake cleanly from other threads.
The tenant queue is start-time-fair: weighted 2:1 interleave, priority
within tenant, idle tenants re-enter at the current virtual time, and
push_front (preemption replay) bypasses both fairness and the bound.
"""
import threading

import pytest

from repro.serving.handles import QueueFull, RequestHandle, TenantQueue


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class Req:
    def __init__(self, uid, tenant="default", priority=0):
        self.uid = uid
        self.tenant = tenant
        self.priority = priority


# ---------------------------------------------------------------------------
# RequestHandle
# ---------------------------------------------------------------------------

def test_feed_is_monotone_and_idempotent():
    h = RequestHandle(1, clock=FakeClock())
    assert h.feed([5, 6]) == 2
    assert h.feed([5, 6]) == 0          # replayed total: no re-delivery
    assert h.feed([5, 6, 7]) == 1       # only the new suffix lands
    assert h.tokens() == [5, 6, 7]


def test_finish_is_idempotent_and_flushes_tail():
    class C:
        gen_tokens = [5, 6, 7, 8]

    clk = FakeClock()
    h = RequestHandle(1, clock=clk)
    h.feed([5, 6])
    clk.t = 3.0
    h.finish(C())
    assert h.tokens() == [5, 6, 7, 8]   # final flush, same stream
    assert h.outcome == "completed" and h.t_done == 3.0
    clk.t = 9.0
    h.finish(C(), outcome="cancelled")  # second transition: no-op
    assert h.outcome == "completed" and h.t_done == 3.0
    assert not h.cancel()               # nothing left to cancel


def test_deltas_stream_across_threads():
    h = RequestHandle(1, clock=FakeClock())
    got = []
    seen = threading.Event()

    def consume():
        for chunk in h.deltas(timeout=10.0):
            got.append(list(chunk))
            seen.set()

    th = threading.Thread(target=consume)
    th.start()
    h.feed([1, 2])
    assert seen.wait(timeout=10.0)       # first chunk delivered before...
    h.feed([1, 2, 3])                    # ...the next feed: 2+ chunks

    class C:
        gen_tokens = [1, 2, 3, 4]

    h.finish(C())
    th.join(timeout=10.0)
    assert not th.is_alive()
    assert [t for c in got for t in c] == [1, 2, 3, 4]
    assert len(got) >= 2                # incremental, not one lump


def test_deltas_timeout_and_error_outcome():
    h = RequestHandle(7, clock=FakeClock())
    with pytest.raises(TimeoutError):
        for _ in h.deltas(timeout=0.01):
            pass
    h.abort("engine fell over")
    with pytest.raises(RuntimeError, match="engine fell over"):
        for _ in h.deltas(timeout=1.0):
            pass
    with pytest.raises(RuntimeError):
        h.result(timeout=1.0)


def test_timings_split():
    clk = FakeClock()
    h = RequestHandle(1, clock=clk)
    h.t_submit = 0.0
    assert h.timings()["e2e_s"] is None          # None until the edge
    h.t_admit = 1.0
    h.t_prefill_done = 1.5
    clk.t = 2.0
    h.feed([4])
    clk.t = 5.0
    h.finish(None, outcome="cancelled")
    t = h.timings()
    assert t["queue_wait_s"] == 1.0
    assert t["prefill_s"] == 0.5
    assert t["decode_s"] == 3.5
    assert t["ttft_s"] == 2.0
    assert t["e2e_s"] == 5.0


def test_status_transitions():
    h = RequestHandle(1, clock=FakeClock())
    h.t_submit = 0.0
    assert h.status == "queued"
    h.t_admit = 0.1
    assert h.status == "running"
    assert h.cancel() and h.cancel_requested
    h.finish(None, outcome="cancelled")
    assert h.status == "done"


# ---------------------------------------------------------------------------
# TenantQueue
# ---------------------------------------------------------------------------

def _drain(q, n=None):
    out = []
    while q and (n is None or len(out) < n):
        r = q.peek()
        q.take(r)
        out.append(r)
    return out


def test_single_tenant_is_fifo():
    q = TenantQueue()
    for i in range(5):
        q.push(Req(i))
    assert [r.uid for r in _drain(q)] == [0, 1, 2, 3, 4]


def test_weighted_fair_interleave():
    q = TenantQueue(weights={"a": 2.0, "b": 1.0})
    for i in range(4):
        q.push(Req(i, "a"))
    for i in range(4):
        q.push(Req(10 + i, "b"))
    order = [r.tenant for r in _drain(q, 6)]
    # 2:1 share while both tenants are backlogged
    assert order.count("a") == 4 and order.count("b") == 2, order


def test_priority_orders_within_tenant_only():
    q = TenantQueue()
    q.push(Req(0, priority=0))
    q.push(Req(1, priority=5))
    q.push(Req(2, priority=5))
    # priority desc, then arrival order within equal priority
    assert [r.uid for r in _drain(q)] == [1, 2, 0]


def test_idle_tenant_reenters_at_current_virtual_time():
    q = TenantQueue()
    for i in range(10):
        q.push(Req(i, "busy"))
    _drain(q, 8)                         # "busy" advances virtual time
    q.push(Req(100, "late"))             # parked tenant arrives late...
    q.push(Req(101, "late"))
    got = [r.tenant for r in _drain(q)]
    # ...and shares from NOW (alternates) instead of draining its backlog
    # first on accumulated credit
    assert got[0] == "late" and got[1] == "busy", got


def test_queue_full_rejects_but_push_front_bypasses():
    q = TenantQueue(max_queue=2)
    q.push(Req(0))
    q.push(Req(1))
    with pytest.raises(QueueFull):
        q.push(Req(2))
    q.push_front(Req(3))                 # preemption replay: never rejected
    assert len(q) == 3
    assert q.peek().uid == 3             # and it wins the next admission


def test_take_nonhead_entry_lazy_deletes():
    q = TenantQueue()
    reqs = [Req(i) for i in range(4)]
    for r in reqs:
        q.push(r)
    q.take(reqs[2])                      # displaced: engine admitted out of
    assert [r.uid for r in _drain(q)] == [0, 1, 3]


def test_drop_removes_everywhere():
    q = TenantQueue(weights={"a": 1.0, "b": 1.0})
    q.push(Req(0, "a"))
    q.push(Req(1, "a"))
    q.push(Req(2, "b"))
    q.push_front(Req(3, "a"))
    removed = q.drop({1, 3})
    assert sorted(r.uid for r in removed) == [1, 3]
    assert len(q) == 2
    assert sorted(r.uid for r in _drain(q)) == [0, 2]
