"""Layer primitive properties (hypothesis where shapes permit)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.models.layers as L
from repro.models.layers import (MaskSpec, apply_rope, attend, attend_full,
                                 causal_mask, conv1d_causal, rms_norm)


def test_rms_norm_scale_invariant_direction():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8))
    w = jnp.zeros((8,))
    y1 = rms_norm(x, w)
    y2 = rms_norm(3.0 * x, w)
    # scale invariance holds only up to eps=1e-5 inside rsqrt(var + eps)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(jnp.mean(y1 * y1, -1)), np.ones(4), rtol=1e-4)


def test_rope_preserves_norm_and_relativity():
    """RoPE is a rotation (norm-preserving) and q.k depends only on the
    relative distance."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (1, 4, 2, 32))
    pos = jnp.array([[0, 5, 9, 21]])
    y = apply_rope(x, pos, theta=10000.0)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    q = jax.random.normal(key, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))
    def dot_at(pq, pk):
        qr = apply_rope(q, jnp.array([[pq]]), 1e4)
        kr = apply_rope(k, jnp.array([[pk]]), 1e4)
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(7, 3) - dot_at(14, 10)) < 1e-4
    assert abs(dot_at(7, 3) - dot_at(8, 3)) > 1e-6   # actually position-dep


def test_gqa_equals_mha_when_repeated():
    """GQA with KV heads replicated to H must equal MHA."""
    key = jax.random.PRNGKey(0)
    B, T, H, hd = 2, 6, 4, 16
    q = jax.random.normal(key, (B, T, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, 2, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, 2, hd))
    mask = causal_mask(T, T)
    out_gqa = attend(q, k, v, mask)
    k_full = jnp.repeat(k, 2, axis=2)
    v_full = jnp.repeat(v, 2, axis=2)
    out_mha = attend(q, k_full, v_full, mask)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha),
                               atol=1e-5)


@given(st.integers(16, 80), st.integers(0, 2), st.sampled_from([0, 8, 24]),
       st.integers(0, 12))
@settings(max_examples=12, deadline=None)
def test_flash_matches_naive(T, kvh_exp, window, prefix):
    KV = 2 ** kvh_exp
    H = KV * 2
    hd = 16
    q = jax.random.normal(jax.random.PRNGKey(T), (1, T, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(T + 1), (1, T, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(T + 2), (1, T, KV, hd))
    spec = MaskSpec(window=window, prefix_len=prefix)
    naive = attend_full(q, k, v, spec)
    old = L._FLASH_THRESHOLD
    try:
        L._FLASH_THRESHOLD = 1
        flash = attend_full(q, k, v, spec, q_chunk=16, k_chunk=16)
    finally:
        L._FLASH_THRESHOLD = old
    np.testing.assert_allclose(np.asarray(naive), np.asarray(flash),
                               atol=2e-5)


def test_flash_cross_attention_rect():
    """Tq != Tk (whisper cross-attention) incl. key padding."""
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 50, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 23, 4, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 23, 4, 16))
    spec = MaskSpec(bidirectional=True)
    naive = attend(q, k, v, jnp.ones((50, 23), bool))
    old = L._FLASH_THRESHOLD
    try:
        L._FLASH_THRESHOLD = 1
        flash = attend_full(q, k, v, spec, q_chunk=16, k_chunk=16)
    finally:
        L._FLASH_THRESHOLD = old
    np.testing.assert_allclose(np.asarray(naive), np.asarray(flash), atol=2e-5)


def test_conv1d_causal_matches_shifted_and_stateful():
    B, T, C, cw = 2, 10, 3, 4
    x = jax.random.normal(jax.random.PRNGKey(0), (B, T, C))
    w = jax.random.normal(jax.random.PRNGKey(1), (cw, C))
    y, state = conv1d_causal(x, w)
    # causality: output t depends only on x[<=t]
    x2 = x.at[:, 5:].set(0.0)
    y2, _ = conv1d_causal(x2, w)
    np.testing.assert_allclose(np.asarray(y[:, :5]), np.asarray(y2[:, :5]),
                               atol=1e-6)
    # streaming: split into two halves with carried state == full
    ya, sa = conv1d_causal(x[:, :6], w)
    yb, _ = conv1d_causal(x[:, 6:], w, state=sa)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([ya, yb], 1)),
                               np.asarray(y), atol=1e-6)
    np.testing.assert_allclose(np.asarray(state), np.asarray(x[:, -cw + 1:]),
                               atol=1e-6)
