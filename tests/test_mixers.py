"""Mixer-level references: SSD vs naive recurrence, RG-LRU scan vs step,
MLA absorbed-vs-full, MoE routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as moe_mod
from repro.models.ssm import ssd_chunked


def naive_ssd(xh, Bc, Cc, dt, A):
    """Token-by-token linear recurrence (the definition SSD must match)."""
    B_, T, H, hd = xh.shape
    G, ds = Bc.shape[2], Bc.shape[3]
    rep = H // G
    h = np.zeros((B_, H, hd, ds))
    ys = np.zeros((B_, T, H, hd))
    for t in range(T):
        da = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])      # (B,H)
        Bt = np.repeat(np.asarray(Bc[:, t]), rep, axis=1)            # (B,H,ds)
        Ct = np.repeat(np.asarray(Cc[:, t]), rep, axis=1)
        xt = np.asarray(xh[:, t])                                    # (B,H,hd)
        h = h * da[:, :, None, None] + np.einsum(
            "bh,bhs,bhd->bhds", np.asarray(dt[:, t]), Bt, xt)
        ys[:, t] = np.einsum("bhs,bhds->bhd", Ct, h)
    return ys, h


@pytest.mark.parametrize("T,chunk", [(32, 8), (48, 16), (16, 16)])
def test_ssd_chunked_matches_naive(T, chunk):
    B_, H, hd, ds = 2, 4, 8, 16
    key = jax.random.PRNGKey(0)
    xh = jax.random.normal(key, (B_, T, H, hd))
    Bc = jax.random.normal(jax.random.PRNGKey(1), (B_, T, 1, ds)) * 0.5
    Cc = jax.random.normal(jax.random.PRNGKey(2), (B_, T, 1, ds)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(3), (B_, T, H)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(4), (H,)) * 0.3)
    y, h = ssd_chunked(xh, Bc, Cc, dt, A, chunk)
    y_ref, h_ref = naive_ssd(xh, Bc, Cc, dt, A)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, atol=1e-4)


def test_ssd_initial_state_continuation():
    """Chunked scan over [a;b] == scan(a) then scan(b, h0=state(a))."""
    B_, T, H, hd, ds, chunk = 1, 32, 2, 8, 8, 8
    xh = jax.random.normal(jax.random.PRNGKey(0), (B_, T, H, hd))
    Bc = jax.random.normal(jax.random.PRNGKey(1), (B_, T, 1, ds)) * 0.5
    Cc = jax.random.normal(jax.random.PRNGKey(2), (B_, T, 1, ds)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(3), (B_, T, H)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(4), (H,)) * 0.3)
    y_full, h_full = ssd_chunked(xh, Bc, Cc, dt, A, chunk)
    y1, h1 = ssd_chunked(xh[:, :16], Bc[:, :16], Cc[:, :16], dt[:, :16], A, chunk)
    y2, h2 = ssd_chunked(xh[:, 16:], Bc[:, 16:], Cc[:, 16:], dt[:, 16:], A,
                         chunk, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-4)


def test_expert_ranks_sort_matches_cumsum():
    fe = jax.random.randint(jax.random.PRNGKey(0), (4096,), 0, 16)
    small = moe_mod._expert_ranks(fe, 16)
    # force the sort-based branch by lying about E via threshold arithmetic:
    big = moe_mod._expert_ranks(jnp.concatenate([fe] * 2), 16)[:4096]
    # independently verify small against numpy
    fe_n = np.asarray(fe)
    cnt, exp = {}, np.zeros_like(fe_n)
    for i, e in enumerate(fe_n):
        exp[i] = cnt.get(int(e), 0)
        cnt[int(e)] = exp[i] + 1
    np.testing.assert_array_equal(np.asarray(small), exp)
    np.testing.assert_array_equal(np.asarray(big), exp)


def test_expert_ranks_sort_branch_exact():
    """Explicitly exercise the argsort path (N*E above threshold)."""
    N, E = 1 << 19, 16    # N*E = 2^23 > 2^22 threshold
    fe = jax.random.randint(jax.random.PRNGKey(1), (N,), 0, E)
    ranks = moe_mod._expert_ranks(fe, E)
    # per-expert ranks must be a permutation of 0..count-1
    fe_n, r_n = np.asarray(fe), np.asarray(ranks)
    for e in range(E):
        rr = np.sort(r_n[fe_n == e])
        np.testing.assert_array_equal(rr, np.arange(len(rr)))


def test_moe_dropless_no_drops_and_gates_normalized(tiny_models):
    cfg, model, params = tiny_models("deepseek-v3-671b")
    mo = cfg.moe
    p = params["segments"]["s1"]["moe"]
    lp = jax.tree.map(lambda a: a[0], p)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, cfg.d_model))
    y_drop, aux = moe_mod.moe_ffn(lp, x, mo, cfg.act, cfg.glu, dropless=True)
    assert jnp.isfinite(y_drop).all()
    assert float(aux) >= 0
    # permutation invariance under dropless routing: shuffling tokens
    # shuffles outputs identically (no capacity interference)
    perm = jax.random.permutation(jax.random.PRNGKey(7), 16)
    xf = x.reshape(16, cfg.d_model)
    y2, _ = moe_mod.moe_ffn(lp, xf[perm].reshape(2, 8, -1), mo, cfg.act,
                            cfg.glu, dropless=True)
    np.testing.assert_allclose(np.asarray(y2.reshape(16, -1)),
                               np.asarray(y_drop.reshape(16, -1)[perm]),
                               atol=1e-4)


def test_mla_step_matches_full(tiny_models):
    """Absorbed-form decode == decompressed full attention (same prefix)."""
    cfg, model, params = tiny_models("deepseek-v3-671b")
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    x = model.embed(params, toks)
    h_full, _, _ = model.hidden(params, x)
    _, cache, _ = model.prefill(params, toks[:, :8], max_len=32)
    xb = model.embed_block(params, toks[:, 8:], cache["lengths"])
    h_blk, _, _, _ = model.step(params, xb, cache)
    np.testing.assert_allclose(np.asarray(h_blk), np.asarray(h_full[:, 8:]),
                               atol=2e-4)
