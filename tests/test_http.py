"""HTTP front-end: routes, SSE == engine-direct streams, disconnect ->
cancel, 429 backpressure, and graceful shutdown.  One module-scoped
server (engine on its driver thread) serves every test."""
import json
import socket
import struct
import threading
import time
import http.client

import jax
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core import online
from repro.models.model import build_model
from repro.serving import Request, ServingEngine
from repro.serving.http import EngineDriver, make_server


@pytest.fixture(scope="module")
def server():
    cfg = tiny_cfg("vicuna-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    eng = ServingEngine(model, params, state, scheduler="continuous",
                        num_slots=4, max_new=32, buckets=(16,),
                        max_queue=64)
    srv = make_server("127.0.0.1", 0, eng, model_id="dvi-tiny",
                      default_max_new=8, request_timeout_s=120.0)
    th = threading.Thread(target=srv.serve_forever,
                          kwargs={"poll_interval": 0.05}, daemon=True)
    th.start()
    yield srv, eng, cfg
    srv.shutdown()
    srv.server_close()
    srv.driver.stop(drain=True)
    th.join(timeout=30.0)


def _get(srv, path):
    conn = http.client.HTTPConnection("127.0.0.1", srv.server_address[1],
                                      timeout=60)
    conn.request("GET", path)
    r = conn.getresponse()
    return r.status, r.getheader("Content-Type"), r.read()


def _post(srv, body, timeout=120):
    conn = http.client.HTTPConnection("127.0.0.1", srv.server_address[1],
                                      timeout=timeout)
    conn.request("POST", "/v1/completions", json.dumps(body),
                 {"Content-Type": "application/json"})
    return conn, conn.getresponse()


def _read_sse(resp):
    toks, finish = [], None
    while True:
        line = resp.readline()
        if not line:
            break
        line = line.strip()
        if not line.startswith(b"data: "):
            continue
        payload = line[len(b"data: "):]
        if payload == b"[DONE]":
            break
        obj = json.loads(payload)
        assert "error" not in obj, obj
        ch = obj["choices"][0]
        toks.extend(ch.get("token_ids") or [])
        if ch.get("finish_reason"):
            finish = ch["finish_reason"]
    return toks, finish


def _prompt(cfg, seed=0, n=12):
    rng = np.random.default_rng(seed)
    return [int(t) for t in rng.integers(2, cfg.vocab_size, n)]


def test_routes(server):
    srv, eng, cfg = server
    st, ct, body = _get(srv, "/healthz")
    assert st == 200 and json.loads(body)["status"] == "ok"
    st, ct, body = _get(srv, "/v1/models")
    assert st == 200
    assert json.loads(body)["data"][0]["id"] == "dvi-tiny"
    st, ct, body = _get(srv, "/metrics")
    assert st == 200 and ct.startswith("text/plain")
    text = body.decode()
    assert "dvi_serving_submitted_total" in text
    assert "dvi_serving_requests_by_tenant" in text
    st, _, _ = _get(srv, "/nope")
    assert st == 404


def test_bad_request_is_400(server):
    srv, eng, cfg = server
    for bad in ({"prompt": []}, {"prompt": "not ints"},
                {"prompt": [1, True, 3]}, {}):
        _, r = _post(srv, bad)
        assert r.status == 400, bad
        assert json.loads(r.read())["error"]["type"] \
            == "invalid_request_error"


def test_sse_stream_matches_blocking_and_engine_direct(server):
    srv, eng, cfg = server
    prompt = _prompt(cfg, seed=5)
    _, r = _post(srv, {"prompt": prompt, "max_tokens": 12})
    assert r.status == 200
    body = json.loads(r.read())
    blocking = body["choices"][0]["token_ids"]
    assert body["usage"]["completion_tokens"] == len(blocking)
    assert set(body["timings"]) == {"queue_wait_s", "prefill_s", "decode_s",
                                    "ttft_s", "e2e_s"}

    _, r = _post(srv, {"prompt": prompt, "max_tokens": 12, "stream": True})
    assert r.status == 200
    sse, finish = _read_sse(r)
    assert finish in ("stop", "length")
    assert sse == blocking               # same engine, same greedy stream

    # engine-direct via the driver: the committed stream is the SAME
    # regardless of transport (greedy streams are schedule-independent)
    drv: EngineDriver = srv.driver
    h = drv.submit(Request(uid=drv.next_uid(),
                           prompt=np.asarray(prompt, np.int32),
                           max_new=12))
    direct = [t for ch in h.deltas(timeout=120.0) for t in ch]
    assert direct == sse


def test_text_field_roundtrips_token_ids(server):
    srv, eng, cfg = server
    prompt = _prompt(cfg, seed=6)
    _, r = _post(srv, {"prompt": prompt, "max_tokens": 6, "stream": True})
    text = r.read().decode()
    joined = "".join(json.loads(line[6:])["choices"][0]["text"]
                     for line in text.splitlines()
                     if line.startswith("data: ")
                     and not line.startswith("data: [DONE]"))
    _, r = _post(srv, {"prompt": prompt, "max_tokens": 6})
    toks = json.loads(r.read())["choices"][0]["token_ids"]
    assert [int(t) for t in joined.split()] == toks


def test_client_disconnect_cancels_at_boundary(server):
    srv, eng, cfg = server
    drv = srv.driver
    before = drv.call(lambda: eng.stats["cancelled"])
    body = json.dumps({"prompt": _prompt(cfg, seed=7), "max_tokens": 32,
                       "stream": True}).encode()
    sk = socket.create_connection(("127.0.0.1", srv.server_address[1]),
                                  timeout=60)
    sk.sendall(b"POST /v1/completions HTTP/1.0\r\n"
               b"Content-Type: application/json\r\n"
               + f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    buf = b""
    while b"token_ids" not in buf:        # first committed chunk arrived
        buf += sk.recv(4096)
    # RST on close so the server's next SSE write fails immediately
    sk.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                  struct.pack("ii", 1, 0))
    sk.close()
    deadline = time.monotonic() + 60
    while time.monotonic() < deadline:
        if drv.call(lambda: eng.stats["cancelled"]) > before:
            break
        time.sleep(0.1)
    assert drv.call(lambda: eng.stats["cancelled"]) == before + 1
    # lane actually retired: engine drains back to idle
    while drv.call(lambda: eng.busy):
        time.sleep(0.05)
    assert drv.call(lambda: sum(s is not None for s in eng._slots)) == 0


def test_backpressure_returns_429(server):
    srv, eng, cfg = server
    drv = srv.driver
    while drv.call(lambda: eng.busy):     # start from an idle engine
        time.sleep(0.05)
    drv.pause()                           # freeze stepping: queue can't drain
    try:
        drv.call(lambda: setattr(eng._tq, "max_queue", 2))
        conns, got429 = [], 0
        for i in range(4):
            conn, r = _post(srv, {"prompt": _prompt(cfg, seed=10 + i),
                                  "max_tokens": 4, "stream": True})
            if r.status == 429:
                got429 += 1
                err = json.loads(r.read())["error"]
                assert err["type"] == "rate_limit_exceeded"
            else:
                assert r.status == 200
                conns.append((conn, r))
        assert got429 == 2                # bound 2: requests 3+4 rejected
    finally:
        drv.call(lambda: setattr(eng._tq, "max_queue", 64))
        drv.resume()
    for conn, r in conns:                 # accepted ones still complete
        toks, finish = _read_sse(r)
        assert finish in ("stop", "length") and toks
    _, _, body = _get(srv, "/metrics")    # rejections surface in telemetry
    line = next(l for l in body.decode().splitlines()
                if l.startswith("dvi_serving_rejected_total"))
    assert float(line.split()[-1]) >= 2
