"""Sliding-window ring cache under wraparound: decode far past the window
and check against full-sequence forward logits (banded mask) — validates
ring slot reuse, slack-slot rollback, and position bookkeeping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.configs.base import DVIConfig
from repro.core import lora, spec
from repro.models.model import build_model
import repro.models.transformer as tfm


@pytest.fixture(scope="module")
def small_window_model(monkeypatch_module=None):
    # pure local attention, window 16 << generated length
    cfg = tiny_cfg("qwen3-0.6b").replace(
        name="swa-test", sliding_window=16, global_attn_every=0,
        num_layers=2, dvi=DVIConfig(split_layer=1, k_spec=3, lora_rank=8,
                                    buffer_slots=256, batch_size=32))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _ar_reference(cfg, model, params, prompt, n_new):
    """Greedy continuation via repeated FULL forward (banded mask oracle)."""
    toks = list(np.asarray(prompt))
    for _ in range(n_new):
        x = model.embed(params, jnp.asarray([toks]))
        h, _, _ = model.hidden(params, x)
        logits = model.logits(params, h[:, -1])
        toks.append(int(jnp.argmax(logits[0])))
    return toks


@pytest.mark.slow
def test_ring_wraparound_matches_full_forward(small_window_model):
    cfg, model, params = small_window_model
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 2,
                                cfg.vocab_size)
    n_new = 40                                  # 2.5x past the window
    ref = _ar_reference(cfg, model, params, prompt[0], n_new)

    r_ar = spec.ar_generate(model, params, prompt, n_new)
    got = np.asarray(r_ar.tokens[0, :int(r_ar.lengths[0])]).tolist()
    assert got == ref[:len(got)], "ring AR diverged from full-forward oracle"

    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    r_sd = spec.speculative_generate(model, params, dvi, prompt, n_new)
    got_sd = np.asarray(r_sd.tokens[0, :int(r_sd.lengths[0])]).tolist()
    n = min(len(got_sd), len(ref))
    assert got_sd[:n] == ref[:n], "speculative ring decode diverged"


def test_ring_capacity_slack():
    """RING_SLACK must exceed max speculative block so live KV never gets
    clobbered by rejected writes."""
    assert tfm.RING_SLACK >= 8
