"""End-to-end behaviour: pretraining reduces loss; online DVI learning
raises acceptance (Fig. 2a dynamics); serving engine learns while serving;
checkpoint round-trips."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.checkpoint import (load_checkpoint, load_lora, save_checkpoint,
                              save_lora)
from repro.core import lora, online
from repro.data import ByteTokenizer, SyntheticTasks, TASK_CATEGORIES
from repro.models.model import build_model
from repro.serving import Request, ServingEngine
from repro.training import pretrain


@pytest.fixture(scope="module")
def trained():
    cfg = tiny_cfg("vicuna-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tasks = SyntheticTasks(cfg.vocab_size, seed=0)
    params, losses = pretrain(
        model, params, tasks.stream(TASK_CATEGORIES, 120, 16, 32, seed=9),
        lr=2e-3)
    return cfg, model, params, tasks, losses


def test_pretrain_reduces_loss(trained):
    _, _, _, _, losses = trained
    assert losses[-1] < losses[0] * 0.5


def test_online_dvi_acceptance_improves(trained):
    cfg, model, params, tasks, _ = trained
    state = online.init_trainer(model, jax.random.PRNGKey(7))
    stream = tasks.stream(TASK_CATEGORIES, 40, 8, 16, seed=1)
    state, hist = online.online_loop(model, params, stream, state,
                                     max_new=20, mode="full", lr=3e-3)
    first = float(np.mean(hist["block_acc"][:12]))
    last = float(np.mean(hist["block_acc"][-12:]))
    # acceptance stays high / never collapses (batch-level noise on a tiny
    # stream is ±0.05, so the margin is deliberately loose; the strong
    # climb assertion lives in benchmarks/table3 where the budget is 3x)
    assert last > first - 0.06
    assert last > 0.5                   # reaches useful acceptance
    assert float(np.mean(hist["mat"][-12:])) > 2.0


def test_serving_engine_learns_and_completes(trained):
    cfg, model, params, tasks, _ = trained
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    eng = ServingEngine(model, params, state, batch_size=4, max_new=12,
                        buckets=(12,))
    for i in range(8):
        eng.submit(Request(uid=i, prompt=tasks.sample("qa", 1, 12, seed=i)[0]))
    outs = eng.run()
    assert len(outs) == 8
    assert eng.stats["updates"] > 0
    assert all(len(o.tokens) >= 12 for o in outs)


def test_checkpoint_roundtrip(trained):
    cfg, model, params, _, _ = trained
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ck.npz")
        save_checkpoint(path, params)
        zeros = jax.tree.map(jnp.zeros_like, params)
        restored = load_checkpoint(path, zeros)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lora_checkpoint_roundtrip(trained):
    cfg, model, _, _, _ = trained
    dvi = lora.init_draft_params(jax.random.PRNGKey(1), cfg)
    dvi = dict(dvi, B=dvi["B"] + 0.5)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "lora.npz")
        save_lora(path, dvi, step=42, baseline=0.7)
        like = lora.init_draft_params(jax.random.PRNGKey(2), cfg)
        dvi2, step, baseline = load_lora(path, like)
        assert step == 42 and abs(baseline - 0.7) < 1e-6
        np.testing.assert_array_equal(np.asarray(dvi["B"]), np.asarray(dvi2["B"]))


def test_byte_tokenizer_deterministic():
    tok = ByteTokenizer(512)
    a = tok.encode("hello world")
    b = tok.encode("hello world")
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 2 and a.max() < 512
