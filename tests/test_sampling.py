"""Speculative *sampling* (beyond-paper, temperature > 0): the rejection
verifier must emit tokens distributed exactly as the target distribution.

1. unit: `rejection_commit` statistics vs theory on fixed toy p/q.
2. integration: sampled generation runs, stays in-vocab, logs tuples.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core import lora, spec
from repro.models.model import build_model


def test_rejection_commit_matches_target_distribution():
    """Single-position check: with K=1 drafted token ~ q, the emitted first
    token (accepted draft OR residual correction) must be ~ p exactly."""
    V = 8
    p = jnp.array([0.30, 0.22, 0.15, 0.12, 0.09, 0.06, 0.04, 0.02])
    q = jnp.array([0.05, 0.05, 0.30, 0.20, 0.10, 0.10, 0.10, 0.10])
    N = 30_000
    keys = jax.random.split(jax.random.PRNGKey(0), N)

    @jax.vmap
    def one(key):
        kd, kr = jax.random.split(key)
        d = jax.random.categorical(kd, jnp.log(q))[None]          # (B=1,)
        d_blk = jnp.stack([d, d], axis=1)                         # (1, K+1)
        dprobs = jnp.broadcast_to(q, (1, 2, V))
        vprobs = jnp.broadcast_to(p, (1, 2, V))
        m, corr = spec.rejection_commit(kr, d_blk, dprobs, vprobs)
        return jnp.where(m[0] >= 1, d_blk[0, 0], corr[0])

    emitted = np.asarray(one(keys))
    freq = np.bincount(emitted, minlength=V) / N
    tv = 0.5 * np.abs(freq - np.asarray(p)).sum()
    assert tv < 0.02, f"total variation {tv:.4f} vs target"


def test_rejection_commit_all_accept_bonus():
    """q == p => every draft accepted (ratio 1), bonus sampled from p."""
    V = 4
    p = jnp.array([0.4, 0.3, 0.2, 0.1])
    d_blk = jnp.array([[0, 1, 2]])                                # K=2
    dprobs = jnp.broadcast_to(p, (1, 3, V))
    vprobs = jnp.broadcast_to(p, (1, 3, V))
    m, corr = spec.rejection_commit(jax.random.PRNGKey(1), d_blk, dprobs,
                                    vprobs)
    assert int(m[0]) == 2                                         # all accepted


def test_sampled_generation_runs(tiny_models):
    cfg, model, params = tiny_models("vicuna-7b")
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 2,
                                 cfg.vocab_size)
    res = spec.speculative_generate(model, params, dvi, prompts, 24,
                                    temperature=0.8, collect=True,
                                    key=jax.random.PRNGKey(3))
    toks = np.asarray(res.tokens)
    lens = np.asarray(res.lengths)
    assert (lens > 8).all()
    for b in range(3):
        assert toks[b, :lens[b]].min() >= 0
        assert toks[b, :lens[b]].max() < cfg.vocab_size
    assert int(res.buffer["count"]) > 0
    # different keys give different samples (it actually samples)
    res2 = spec.speculative_generate(model, params, dvi, prompts, 24,
                                     temperature=0.8,
                                     key=jax.random.PRNGKey(99))
    assert not bool(jnp.all(res.tokens == res2.tokens))


def test_temperature_zero_unchanged(tiny_models):
    """temperature=0 must remain the paper's exact greedy path."""
    cfg, model, params = tiny_models("vicuna-7b")
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 2,
                                 cfg.vocab_size)
    r1 = spec.speculative_generate(model, params, dvi, prompts, 16)
    r2 = spec.ar_generate(model, params, prompts, 16)
    for b in range(2):
        n = min(int(r1.lengths[b]), int(r2.lengths[b]))
        assert bool(jnp.all(r1.tokens[b, :n] == r2.tokens[b, :n]))
