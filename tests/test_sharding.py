"""Partitioner rules + HLO analyzer unit tests (no multi-device needed —
the real 512-device proof is the dry-run; tests here cover the pure logic)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import hlo_analysis as H
from repro.launch import sharding as shd
from repro.models.model import build_model


class FakeMesh:
    """Duck-typed mesh for spec-rule tests (axis sizes only)."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)
        self.size = int(np.prod(list(shape.values())))


MESH = FakeMesh({"data": 16, "model": 16})


def _specs(name):
    cfg = get_config(name)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    return shapes, shd.param_specs(shapes, MESH)


@pytest.mark.parametrize("name", ["llama3-405b", "deepseek-v3-671b",
                                  "mamba2-370m", "recurrentgemma-9b"])
def test_specs_divisibility(name):
    """Every assigned axis must divide its dim; no axis reused in one spec."""
    shapes, specs = _specs(name)
    flat_sh = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    def axes_of(entry):
        if entry is None:
            return ()
        return entry if isinstance(entry, tuple) else (entry,)

    for (path, leaf), spec in zip(flat_sh, flat_sp):
        used = [a for e in spec for a in axes_of(e)]
        assert len(used) == len(set(used)), f"axis reuse at {path}"
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * 8):
            size = 1
            for a in axes_of(entry):
                size *= MESH.shape[a]
            assert dim % size == 0, f"{path}: {dim} % {entry}"


def test_llama3_2d_sharded_weights():
    shapes, specs = _specs("llama3-405b")
    wq = specs["segments"]["s1"]["wq"]
    assert wq == P(None, "data", "model")
    emb = specs["embed"]
    assert emb == P("model", "data")


def test_moe_expert_parallel():
    shapes, specs = _specs("deepseek-v3-671b")
    moe_segs = [s for s in specs["segments"].values()
                if isinstance(s, dict) and "moe" in s]
    assert moe_segs, "no MoE segment found"
    we = moe_segs[0]["moe"]["we_gate"]
    assert we[1] == "model"       # experts over model (expert parallelism)
    assert we[2] == "data"        # expert d_model over data (FSDP)


def test_batch_axes_fallback():
    assert shd.batch_axes(MESH, 256) == ("data",)
    assert shd.batch_axes(MESH, 1) is None
    m3 = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert shd.batch_axes(m3, 256) == ("pod", "data")
    assert shd.batch_axes(m3, 16) is None or shd.batch_axes(m3, 16) == ("pod",)


HLO_SAMPLE = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[8,8] get-tuple-element(%p), index=1
  %dotop = f32[8,8] dot(%g1, %g1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%dotop), replica_groups=[4,2]<=[8], to_apply=%sum
  ROOT %t = (s32[], f32[8,8]) tuple(%g0, %ar)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %iv = s32[] get-tuple-element(%p2), index=0
  %c = s32[] constant(12)
  ROOT %cmp = pred[] compare(%iv, %c), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_hlo_analyzer_trip_counts():
    res = H.analyze(HLO_SAMPLE, entry="main")
    # dot: 2*8*8*8 = 1024 flops, x12 trips
    assert res["dot_flops_per_device"] == 1024 * 12
    ar = res["collectives_per_kind"]["all-reduce"]
    assert ar["count"] == 12
    assert ar["payload_bytes"] == 8 * 8 * 4 * 12
    # wire: 2 * bytes * (2-1)/2 per op (group size 2)
    assert abs(ar["wire_bytes"] - 12 * 2 * 256 * 0.5) < 1e-6
