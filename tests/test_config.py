"""EngineConfig / ModelSpec: the one shared CLI + constructor surface.

The contract: every EngineConfig field maps onto a real ServingEngine
constructor parameter (no silent drift as the engine grows knobs), and
``to_argv() -> add_args/from_args`` round-trips exactly, so a config can
be shipped across a process boundary as flags (load_gen re-creating a
server's engine for stream verification).
"""
import argparse
import inspect

from repro.serving.config import (EngineConfig, ModelSpec,
                                  format_tenant_weights,
                                  parse_tenant_weights)


def _parse(argv, defaults=None):
    ap = argparse.ArgumentParser()
    EngineConfig.add_args(ap, defaults)
    return EngineConfig.from_args(ap.parse_args(argv))


def test_defaults_round_trip():
    c = EngineConfig()
    assert _parse(c.to_argv()) == c
    assert _parse([]) == c               # no flags == defaults


def test_nondefault_round_trip():
    c = EngineConfig(scheduler="sync", num_slots=3, batch_size=4, max_new=7,
                     bucket=32, sync_every=2, learn=False, kv_pages=48,
                     kv_page_size=8, prefix_cache=True, prefill_chunk=8,
                     adaptive_k=True, k_min=2, k_max=5, max_queue=9,
                     tenant_weights={"gold": 3.0, "free": 1.0},
                     telemetry=True, profile_dir="/tmp/prof")
    assert _parse(c.to_argv()) == c


def test_engine_kwargs_match_engine_signature():
    from repro.serving.engine import ServingEngine
    kw = EngineConfig().engine_kwargs()
    params = inspect.signature(ServingEngine.__init__).parameters
    unknown = set(kw) - set(params)
    assert not unknown, f"EngineConfig fields with no engine param: {unknown}"
    assert "buckets" in kw and kw["buckets"] == (EngineConfig().bucket,)


def test_tenant_weights_parse_format():
    assert parse_tenant_weights("") is None
    assert parse_tenant_weights("a:2,b:1") == {"a": 2.0, "b": 1.0}
    assert parse_tenant_weights("solo") == {"solo": 1.0}
    w = {"gold": 2.5, "free": 1.0}
    assert parse_tenant_weights(format_tenant_weights(w)) == w


def test_batch_alias():
    assert _parse(["--batch", "5"]).batch_size == 5
    assert _parse(["--batch-size", "6"]).batch_size == 6


def test_model_spec_round_trip():
    ap = argparse.ArgumentParser()
    ModelSpec.add_args(ap)
    s = ModelSpec.from_args(ap.parse_args(["--arch", "vicuna-7b",
                                           "--seed", "3",
                                           "--pretrain-steps", "17"]))
    assert s == ModelSpec(arch="vicuna-7b", tiny=True, seed=3,
                          pretrain_steps=17)
    s2 = ModelSpec.from_args(ap.parse_args(["--full-size"]))
    assert s2.tiny is False
