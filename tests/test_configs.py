"""Config registry + published-size checks."""
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, INPUT_SHAPES, get_config

# published parameter counts (billions) with acceptable relative slack —
# our counting is analytic, the citations are the source of truth.
PUBLISHED_B = {
    "llama3-405b": (405.0, 0.02),
    "recurrentgemma-9b": (9.0, 0.15),     # RG-LRU gate layout approximated
    "qwen2.5-14b": (14.8, 0.05),
    "llama4-scout-17b-a16e": (109.0, 0.05),
    "whisper-large-v3": (1.55, 0.05),
    "qwen3-0.6b": (0.6, 0.1),
    "qwen3-1.7b": (1.7, 0.15),
    "mamba2-370m": (0.37, 0.15),
    "deepseek-v3-671b": (671.0, 0.02),
    "vicuna-7b": (6.7, 0.03),
}


def test_registry_has_all_assigned():
    assert len(ASSIGNED_ARCHS) == 10
    assert len(set(ASSIGNED_ARCHS)) == 10


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_config_valid(name):
    cfg = get_config(name)
    cfg.validate()
    assert cfg.citation


@pytest.mark.parametrize("name", list(PUBLISHED_B))
def test_param_count_matches_published(name):
    cfg = get_config(name)
    target, slack = PUBLISHED_B[name]
    got = cfg.param_count() / 1e9
    assert abs(got - target) / target < slack, f"{name}: {got:.2f}B vs {target}B"


def test_deepseek_active_params():
    cfg = get_config("deepseek-v3-671b")
    active = cfg.active_param_count() / 1e9
    assert 33 < active < 42   # published: 37B activated


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_tiny_variants_reduced(name):
    t = get_config(name, tiny=True)
    t.validate()
    assert t.d_model <= 512
    assert t.num_layers <= 4
    if t.moe is not None:
        assert t.moe.num_experts <= 4


def test_input_shapes():
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}
    assert INPUT_SHAPES["long_500k"].seq_len == 524_288
    assert INPUT_SHAPES["train_4k"].global_batch == 256
