"""Baseline generators: losslessness + integration with DVI ablation modes."""
import jax
import jax.numpy as jnp
import pytest

from conftest import tiny_cfg
from repro.configs.base import DVIConfig
from repro.core import baselines, lora, spec
from repro.models.model import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg("vicuna-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dcfg = cfg.replace(name="drafter", num_layers=2,
                       dvi=DVIConfig(split_layer=1))
    draft = build_model(dcfg)
    d_params = draft.init(jax.random.PRNGKey(1))
    prompts = jax.random.randint(jax.random.PRNGKey(2), (3, 8), 2,
                                 cfg.vocab_size)
    r_ar = spec.ar_generate(model, params, prompts, 20)
    return cfg, model, params, draft, d_params, prompts, r_ar


def _lossless(r_ar, r, B=3):
    for b in range(B):
        n = min(int(r_ar.lengths[b]), int(r.lengths[b]))
        if not bool(jnp.all(r_ar.tokens[b, :n] == r.tokens[b, :n])):
            return False
    return True


def test_two_model_sd_lossless(setup):
    cfg, model, params, draft, d_params, prompts, r_ar = setup
    r = baselines.two_model_generate(model, params, draft, d_params,
                                     prompts, 20)
    assert _lossless(r_ar, r)
    assert int(r.blocks) > 0


def test_medusa_lossless(setup):
    cfg, model, params, draft, d_params, prompts, r_ar = setup
    heads = baselines.init_medusa_heads(jax.random.PRNGKey(9), model, 3)
    r = baselines.medusa_generate(model, params, heads, prompts, 20)
    assert _lossless(r_ar, r)
    mat = float(r.committed) / float(r.blocks)
    assert mat >= 1.9   # lm token always accepted => MAT >= ~2


def test_static_self_spec_is_dvi_at_init(setup):
    """Zhang'23-style static self-speculation == DVI with untrained LoRA."""
    cfg, model, params, draft, d_params, prompts, r_ar = setup
    dvi = lora.init_draft_params(jax.random.PRNGKey(3), cfg)
    assert float(jnp.abs(dvi["B"]).sum()) == 0.0    # B=0 <=> frozen head @ h_k
    r = spec.speculative_generate(model, params, dvi, prompts, 20)
    assert _lossless(r_ar, r)
