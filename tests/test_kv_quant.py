"""int8 KV cache (beyond-paper, cfg.kv_quant): halves decode cache bytes.

Quantization perturbs the model slightly, so prefill+step tracks the
full-precision path within tolerance — but DVI remains EXACTLY lossless
with respect to its own (quantized) target path, because drafter and
verifier read the same cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core import lora, spec
from repro.models.model import build_model
from repro.models.transformer import kv_dequantize, kv_quantize


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 7, 4, 32)) * 3.0
    q, s = kv_quantize(x)
    assert q.dtype == jnp.int8
    xr = kv_dequantize(q, s, jnp.float32)
    rel = float(jnp.abs(xr - x).max() / jnp.abs(x).max())
    assert rel < 0.01                      # 127-level symmetric quant


@pytest.fixture(scope="module")
def qmodel():
    cfg = tiny_cfg("qwen3-0.6b").replace(kv_quant=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_cache_is_int8(qmodel):
    cfg, model, params = qmodel
    cache = model.init_cache(2, 32)
    seg = cache["segs"]["s1"]
    assert seg["k"].dtype == jnp.int8
    assert "ks" in seg and seg["ks"].shape == seg["k"].shape[:-1]


def test_quantized_step_tracks_full_precision(qmodel):
    cfg, model, params = qmodel
    fp_model = build_model(cfg.replace(kv_quant=False))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    _, cache_q, _ = model.prefill(params, toks[:, :8], max_len=32)
    _, cache_f, _ = fp_model.prefill(params, toks[:, :8], max_len=32)
    xb = model.embed_block(params, toks[:, 8:], cache_q["lengths"])
    h_q, _, _, _ = model.step(params, xb, cache_q)
    h_f, _, _, _ = fp_model.step(params, xb, cache_f)
    rel = float(jnp.abs(h_q - h_f).max() / (jnp.abs(h_f).max() + 1e-9))
    assert rel < 0.05, f"int8 cache diverged {rel:.3f} from fp"


def test_dvi_still_lossless_under_quantized_cache(qmodel):
    """Drafter and verifier share the quantized cache, so the committed
    stream still equals (quantized-cache) greedy AR exactly."""
    cfg, model, params = qmodel
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 2,
                                 cfg.vocab_size)
    r_ar = spec.ar_generate(model, params, prompts, 20)
    r_sd = spec.speculative_generate(model, params, dvi, prompts, 20)
    for b in range(2):
        n = min(int(r_ar.lengths[b]), int(r_sd.lengths[b]))
        assert bool(jnp.all(r_ar.tokens[b, :n] == r_sd.tokens[b, :n]))
