"""The paper's core guarantees as properties.

1. LOSSLESSNESS: DVI's committed stream == plain greedy AR decoding of the
   target path, for every architecture family (incl. stateful-mixer
   rollback and MoE dropless determinism).
2. Buffer tuples have the accept-prefix structure (r = 1...1 then 0).
3. MAT accounting: committed == sum over blocks of (accepted + 1).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import ARCHS, make_aux, tiny_cfg
from repro.core import lora, spec
from repro.models.model import build_model


def _match(r_ar, r_sd, B, cap):
    for b in range(B):
        n = min(int(r_ar.lengths[b]), int(r_sd.lengths[b]), cap)
        if not bool(jnp.all(r_ar.tokens[b, :n] == r_sd.tokens[b, :n])):
            return False, b, n
    return True, -1, -1


@pytest.mark.parametrize("name", ARCHS)
def test_lossless_all_archs(tiny_models, name):
    cfg, model, params = tiny_models(name)
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    B, Tp, new = 2, 8, 20
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, Tp), 2,
                                 cfg.vocab_size)
    aux = make_aux(cfg, B)
    r_ar = spec.ar_generate(model, params, prompts, new, aux_inputs=aux)
    r_sd = spec.speculative_generate(model, params, dvi, prompts, new,
                                     collect=True, aux_inputs=aux)
    ok, b, n = _match(r_ar, r_sd, B, Tp + new)
    assert ok, f"{name}: diverged for seq {b} within {n} tokens"


@pytest.mark.slow
@given(st.integers(0, 2 ** 16), st.integers(1, 6))
@settings(max_examples=8, deadline=None)
def test_lossless_property_random(seed, k_spec):
    """Losslessness holds for random weights, seeds, and draft depths."""
    cfg = tiny_cfg("vicuna-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed % 97))
    dvi = lora.init_draft_params(jax.random.PRNGKey(seed % 31), cfg)
    # perturb LoRA B so the drafter disagrees with the verifier sometimes
    dvi = dict(dvi, B=jax.random.normal(jax.random.PRNGKey(seed), dvi["B"].shape) * 0.05)
    prompts = jax.random.randint(jax.random.PRNGKey(seed), (2, 6), 2,
                                 cfg.vocab_size)
    r_ar = spec.ar_generate(model, params, prompts, 16)
    r_sd = spec.speculative_generate(model, params, dvi, prompts, 16,
                                     k_spec=k_spec)
    ok, b, n = _match(r_ar, r_sd, 2, 22)
    assert ok


def test_buffer_reward_prefix_structure(tiny_models):
    """Logged rewards within a block must be 1^m 0 (accepts then first
    reject); counterfactual positions are never logged."""
    cfg, model, params = tiny_models("vicuna-7b")
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (3, 8), 2,
                                 cfg.vocab_size)
    res = spec.speculative_generate(model, params, dvi, prompts, 24,
                                    collect=True)
    buf = res.buffer
    cnt = int(buf["count"])
    assert cnt > 0
    pos = np.asarray(buf["pos"][:cnt])
    rew = np.asarray(buf["reward"][:cnt])
    assert set(np.unique(rew)) <= {0.0, 1.0}
    # within each logged run, position index resets at 1 and rewards are a
    # 1-prefix: a reward 1 at pos i>1 implies reward 1 at pos i-1 (same block)
    for i in range(cnt):
        if pos[i] > 1 and rew[i] == 1.0:
            assert rew[i - 1] == 1.0 and pos[i - 1] == pos[i] - 1


def test_mat_accounting(tiny_models):
    cfg, model, params = tiny_models("vicuna-7b")
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 2,
                                 cfg.vocab_size)
    res = spec.speculative_generate(model, params, dvi, prompts, 24)
    assert int(res.committed) == int(res.accepted_drafts) + int(res.blocks)
    assert int(res.drafted) == cfg.dvi.k_spec * int(res.blocks)
    mat = float(res.committed) / float(res.blocks)
    assert 1.0 <= mat <= cfg.dvi.k_spec + 1


def test_ar_equals_kspec0(tiny_models):
    cfg, model, params = tiny_models("qwen3-0.6b")
    prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 2,
                                 cfg.vocab_size)
    r1 = spec.ar_generate(model, params, prompts, 16)
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    r2 = spec.speculative_generate(model, params, dvi, prompts, 16, k_spec=0)
    assert bool(jnp.all(r1.tokens == r2.tokens))
    assert float(r1.committed) / float(r1.blocks) == 1.0   # AR MAT == 1
