"""Per-assigned-architecture smoke tests (deliverable f):

For every architecture, instantiate the REDUCED same-family variant
(<= 4 layers, d_model <= 512, <= 4 experts) and run one forward/train step
on CPU asserting output shapes + no NaNs.  Full configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from conftest import ARCHS, make_aux
from repro.optim import adamw_init
from repro.training import make_pretrain_step


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_no_nans(tiny_models, name):
    cfg, model, params = tiny_models(name)
    B, T = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    aux_in = make_aux(cfg, B)
    logits, aux = model.forward_train(params, toks, aux_in)
    P = cfg.vision.num_patches if cfg.vision is not None else 0
    assert logits.shape == (B, T + P, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCHS)
def test_one_train_step(tiny_models, name):
    cfg, model, params = tiny_models(name)
    B, T = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg.vocab_size)
    aux_in = make_aux(cfg, B)
    step = make_pretrain_step(model, lr=1e-3, donate=False)
    opt = adamw_init(params)
    params2, opt2, metrics = step(params, opt, toks, aux_in)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["gnorm"]) > 0
    # at least one leaf actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step_shapes(tiny_models, name):
    """One-token decode against a prefilled cache (serve-path smoke)."""
    cfg, model, params = tiny_models(name)
    B = 2
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, 8), 0, cfg.vocab_size)
    aux_in = make_aux(cfg, B)
    _, cache, _ = model.prefill(params, toks, aux_in, max_len=32)
    xb = model.embed_block(params, toks[:, -1:], cache["lengths"])
    h, cache2, cands, _ = model.step(params, xb, cache)
    assert h.shape == (B, 1, cfg.d_model)
    assert bool(jnp.isfinite(h).all())
    cache3 = model.commit(cache2, cands, jnp.ones((B,), jnp.int32))
    assert bool(jnp.all(cache3["lengths"] == cache["lengths"] + 1))
