"""Per-lane adaptive speculation depth (repro.core.schedule.DepthConfig +
the k_lane threading through spec_superstep and the serving engine).

Covers the adaptive-depth contract (ROADMAP):

1. PINNED controller == main: running the ragged-depth code path with depth
   pinned at k_spec (k_lane full of K at the spec level; k_min=k_max=k_init
   at the engine level) must produce bit-identical streams and counters to
   the fixed-K path — greedy and rejection-sampled, contiguous and paged,
   sync_every 1 and 8.
2. Controller properties: depth stays in [k_min, min(k_max, k_hi)], rises
   on sustained acceptance, falls on sustained rejection, freezes on masked
   lanes, and the host-side `max_depth_rises` bound is never beaten by the
   in-graph controller.
3. Engine state hygiene: a recycled slot must NOT inherit the previous
   request's depth/EMA (reset at admission).
4. Page-reservation safety: an adversarial controller that swings depth
   from the floor to the ceiling inside a superstep, on a tight pool, must
   neither corrupt streams (vs a contiguous fixed-K reference) nor leak or
   overrun pages — reservations use worst-case K_max, growth uses live k
   plus the rise bound, so provisioning always covers the realized depth.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core import lora, online, spec
from repro.core.schedule import DepthConfig, depth_update, init_depth_state, \
    max_depth_rises
from repro.models.model import build_model
from repro.serving import Request, ServingEngine

EOS = 1


@pytest.fixture(scope="module")
def backbone():
    cfg = tiny_cfg("vicuna-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    return cfg, model, params, dvi


def _prefill(model, prompts, params):
    _, cache, _ = model.prefill(params, prompts[:, :-1], max_len=96)
    return cache, prompts[:, -1]


def _prefill_paged(cfg, model, params, prompts, ps=4, mps=24):
    import repro.models.transformer as tfm
    from repro.serving.kv_pool import KVPool, pages_for
    B, Tp = prompts.shape
    K = cfg.dvi.k_spec
    pool = KVPool(num_pages=B * mps, page_size=ps)
    cache = model.init_paged_cache(B, pool.num_pages, ps, mps)
    for b in range(B):
        need = pages_for(Tp - 1 + 10 * (K + 1), ps)
        row = np.full(mps, -1, np.int32)
        row[:need] = pool.alloc(need, owner=b)
        cache = tfm.map_slot_pages(cache, jnp.int32(b), jnp.asarray(row))
        _, pc, _ = model.prefill(params, prompts[b:b + 1, :-1],
                                 max_len=Tp - 1)
        cache = tfm.insert_slot(cfg, cache, pc, jnp.int32(b))
    return cache, prompts[:, -1]


# ---------------------------------------------------------------------------
# 1. pinned controller == main (spec level: greedy + sampled x layouts)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("steps", [1, 8])
@pytest.mark.parametrize("temperature", [0.0, 0.7])
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_pinned_k_lane_bit_identical(backbone, steps, temperature, layout):
    cfg, model, params, dvi = backbone
    K = cfg.dvi.k_spec
    B, Tp = 3, 8
    prompts = jax.random.randint(jax.random.PRNGKey(7), (B, Tp), 2,
                                 cfg.vocab_size)
    budget = jnp.asarray(np.array([4, 9, 30], np.int32))
    key = jax.random.PRNGKey(99)
    pf = _prefill_paged if layout == "paged" else _prefill
    pf_args = (cfg, model, params, prompts) if layout == "paged" else \
        (model, prompts, params)

    cache, pending = pf(*pf_args)
    ref = spec.spec_superstep(model, params, dvi, pending, cache,
                              steps=steps, budget=budget, eos_id=EOS,
                              temperature=temperature, key=key)
    cache, pending = pf(*pf_args)
    pin = spec.spec_superstep(model, params, dvi, pending, cache,
                              steps=steps, budget=budget, eos_id=EOS,
                              temperature=temperature, key=key,
                              k_lane=jnp.full((B,), K, jnp.int32))

    np.testing.assert_array_equal(np.asarray(ref.gen_buf),
                                  np.asarray(pin.gen_buf))
    np.testing.assert_array_equal(np.asarray(ref.gen_count),
                                  np.asarray(pin.gen_count))
    np.testing.assert_array_equal(np.asarray(ref.done), np.asarray(pin.done))
    np.testing.assert_array_equal(np.asarray(ref.lane_committed),
                                  np.asarray(pin.lane_committed))
    np.testing.assert_array_equal(np.asarray(ref.lane_accepted),
                                  np.asarray(pin.lane_accepted))
    # fixed path reports K*blocks drafted; pinned ragged path must agree
    np.testing.assert_array_equal(np.asarray(ref.lane_drafted),
                                  np.asarray(pin.lane_drafted))
    np.testing.assert_array_equal(np.asarray(ref.pending),
                                  np.asarray(pin.pending))


# ---------------------------------------------------------------------------
# 1b. pinned controller == main (engine level: layouts x sync_every)
# ---------------------------------------------------------------------------

def _requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        Tp = int(rng.choice([6, 9, 12]))
        mn = int(rng.choice([6, 10, 16]))
        p = np.asarray(jax.random.randint(jax.random.PRNGKey(100 + i), (Tp,),
                                          2, cfg.vocab_size), np.int32)
        reqs.append(Request(uid=i, prompt=p, max_new=mn))
    return reqs


def _serve(model, params, reqs, **kw):
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    eng = ServingEngine(model, params, state, scheduler="continuous",
                        max_new=16, **kw)
    for r in reqs:
        eng.submit(r)
    outs = eng.run(max_steps=2000)
    assert len(outs) == len(reqs)
    return eng, {o.uid: o.gen_tokens.tolist() for o in outs}


@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("sync_every", [1, 8])
def test_engine_pinned_adaptive_matches_fixed(backbone, layout, sync_every):
    cfg, model, params, _ = backbone
    K = cfg.dvi.k_spec
    reqs = _requests(cfg, 5)
    kw = dict(num_slots=2, sync_every=sync_every)
    if layout == "paged":
        kw.update(cache_len=40, kv_pages=40, kv_page_size=4)
    ref_eng, ref = _serve(model, params, reqs, **kw)
    pin_eng, pin = _serve(model, params, reqs, adaptive_k=True,
                          depth_cfg=DepthConfig(k_min=K, k_max=K, k_init=K),
                          **kw)
    assert pin == ref, f"pinned adaptive diverged ({layout}, s{sync_every})"
    # pinned depth must also draft exactly what fixed K drafts
    assert pin_eng.stats["drafted"] == ref_eng.stats["drafted"]
    assert pin_eng.stats["blocks"] == ref_eng.stats["blocks"]


# ---------------------------------------------------------------------------
# 2. controller properties
# ---------------------------------------------------------------------------

def _run_controller(dc, ms, live=None, k_hi=None, n=4):
    k, ema, cool = init_depth_state(dc, n)
    traj = [np.asarray(k)]
    for m in ms:
        live_t = jnp.ones((n,), bool) if live is None else live
        k, ema, cool = depth_update(dc, k, ema, cool,
                                    jnp.asarray(m, jnp.int32), live_t,
                                    k_hi=k_hi)
        traj.append(np.asarray(k))
    return np.stack(traj), np.asarray(ema), np.asarray(cool)


def test_depth_stays_in_bounds_random():
    dc = DepthConfig(k_min=1, k_max=4, k_init=2, cooldown=1,
                     hi=0.6, lo=0.4, ema_alpha=0.9)
    rng = np.random.default_rng(0)
    ms = [rng.integers(0, 5, size=4) for _ in range(64)]
    traj, _, _ = _run_controller(dc, ms)
    assert traj.min() >= dc.k_min and traj.max() <= dc.k_max


def test_depth_respects_per_lane_ceiling():
    dc = DepthConfig(k_min=1, k_max=4, k_init=1, cooldown=1,
                     hi=0.1, lo=0.05, ema_init=0.9)   # always wants to rise
    k_hi = jnp.asarray([1, 2, 3, 4], jnp.int32)       # provisioned depths
    traj, _, _ = _run_controller(dc, [np.full(4, 4)] * 10, k_hi=k_hi)
    np.testing.assert_array_equal(traj[-1], [1, 2, 3, 4])


def test_depth_monotone_response():
    """Sustained full acceptance climbs to k_max; sustained rejection sinks
    to k_min — and each trajectory is monotone."""
    dc = DepthConfig(k_min=1, k_max=4, k_init=2, cooldown=1,
                     ema_alpha=0.5)
    up, _, _ = _run_controller(dc, [np.array([4] * 4)] * 12)   # m = k always
    dn, _, _ = _run_controller(dc, [np.zeros(4)] * 12)
    assert (np.diff(up[:, 0]) >= 0).all() and up[-1, 0] == dc.k_max
    assert (np.diff(dn[:, 0]) <= 0).all() and dn[-1, 0] == dc.k_min


def test_masked_lanes_frozen():
    dc = DepthConfig(k_min=1, k_max=4, k_init=2, cooldown=1, ema_alpha=0.9)
    live = jnp.asarray([True, False, True, False])
    traj, ema, _ = _run_controller(dc, [np.zeros(4)] * 8, live=live)
    assert traj[-1][0] == dc.k_min and traj[-1][2] == dc.k_min
    assert traj[-1][1] == dc.k_init and traj[-1][3] == dc.k_init
    assert ema[1] == pytest.approx(dc.ema_init)      # EMA untouched too


@pytest.mark.parametrize("cool0", [0, 1, 3, 7])
@pytest.mark.parametrize("cooldown", [1, 2, 4])
def test_max_depth_rises_bounds_controller(cool0, cooldown):
    """The host-side bound must dominate the most rise-hungry stream the
    in-graph controller can see (full acceptance every block)."""
    dc = DepthConfig(k_min=1, k_max=64, k_init=1, cooldown=cooldown,
                     hi=0.1, lo=0.05, ema_init=1.0)
    for steps in (1, 2, 4, 8, 16):
        k = jnp.asarray([1], jnp.int32)
        ema = jnp.asarray([1.0], jnp.float32)
        cool = jnp.asarray([cool0], jnp.int32)
        for _ in range(steps):
            k, ema, cool = depth_update(dc, k, ema, cool,
                                        k, jnp.asarray([True]))
        rises = int(k[0]) - 1
        assert rises <= max_depth_rises(dc, steps, cool0), (
            f"steps={steps}: controller rose {rises}x, bound "
            f"{max_depth_rises(dc, steps, cool0)}")


# ---------------------------------------------------------------------------
# 3. slot reuse resets controller state
# ---------------------------------------------------------------------------

def test_slot_reuse_resets_depth_state(backbone):
    cfg, model, params, _ = backbone
    K = cfg.dvi.k_spec
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    # aggressive downward controller: the (untrained) drafter's rejections
    # drag the single lane to the floor within one request
    dc = DepthConfig(k_min=1, k_max=K, k_init=K, cooldown=1,
                     ema_alpha=0.9, hi=0.95, lo=0.80, ema_init=0.9)
    eng = ServingEngine(model, params, state, scheduler="continuous",
                        num_slots=1, max_new=16, sync_every=1,
                        adaptive_k=True, depth_cfg=dc)
    reqs = _requests(cfg, 2, seed=11)
    eng.submit(reqs[0])
    eng.run(max_steps=500)
    assert int(eng._k_host[0]) < K, "first request should have throttled"
    assert float(eng._ema_host[0]) < dc.lo
    # second request recycles slot 0: admission must restart depth/EMA at
    # init, not inherit the stale throttled state
    eng.submit(reqs[1])
    eng._admit_waiting()
    assert int(eng._k_host[0]) == dc.k_init
    assert float(eng._ema_host[0]) == pytest.approx(dc.ema_init)
    assert int(eng._cool_host[0]) == 0
    outs = eng.run(max_steps=500)
    assert len(outs) == 1


# ---------------------------------------------------------------------------
# 4. page-reservation safety under depth swings on a tight pool
# ---------------------------------------------------------------------------

def test_paged_adaptive_swings_tight_pool(backbone):
    """Adversarial controller: lanes admit at the floor and climb to the
    ceiling within a superstep (cooldown=1, rise-always band).  On a tight
    pool this maximizes the gap between admission-time depth and realized
    depth — reservations (worst-case K_max) and growth (live k + rise
    bound) must still cover every eager draft write: streams match the
    contiguous fixed-K reference and the pool drains clean."""
    cfg, model, params, _ = backbone
    K = cfg.dvi.k_spec
    reqs = _requests(cfg, 5, seed=2)
    _, ref = _serve(model, params, reqs, num_slots=2, sync_every=8)
    dc = DepthConfig(k_min=1, k_max=K, k_init=1, cooldown=1,
                     hi=0.1, lo=0.05, ema_init=0.9)    # floor -> ceiling
    for pages in (40, 16):          # ample, and tight enough to preempt
        eng, got = _serve(model, params, reqs, num_slots=2, sync_every=8,
                          cache_len=40, kv_pages=pages, kv_page_size=4,
                          adaptive_k=True, depth_cfg=dc)
        assert got == ref, f"paged adaptive (pages={pages}) diverged"
        assert eng.kv_stats()["used_pages"] == 0, "pool must drain"
    # the swing actually happened: lanes ended above the floor
    assert int(np.max(eng._k_host)) > 1
