"""Prefix caching / copy-on-write page sharing: (1) the pool's refcount +
content-index + LRU-eviction invariants hold under random interleavings of
acquire/publish/alloc/free; (2) watermark math counts evictable pages as
headroom and reclaims them lazily; (3) splicing shared prefix pages into a
lane produces BIT-IDENTICAL committed streams to cold prefill — greedy and
rejection-sampled alike — at the spec level and through the full engine,
including the COW partial-page path and preemption under page scarcity;
(4) refcounts return to baseline after drain (no leak, no stuck page)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import tiny_cfg
from repro.core import lora, online, spec
from repro.models.model import build_model
import repro.models.transformer as tfm
from repro.serving import Request, ServingEngine
from repro.serving.kv_pool import KVPool, pages_for


# ---------------------------------------------------------------------------
# 1) pool unit + property tests
# ---------------------------------------------------------------------------

def test_prefix_pool_roundtrip():
    """publish -> acquire shares full pages by refcount and offers the
    trailing partial page as a COW source; release parks published pages as
    evictable; re-acquire revives them; eviction drops the index."""
    pool = KVPool(num_pages=8, page_size=4)
    prompt = list(range(10, 20))                     # 2 full pages + 2 tail
    pool.alloc(pages_for(len(prompt), 4), owner=1)
    assert pool.publish_prefix(1, prompt) == 3       # 2 full + 1 partial
    p1 = pool.owned(1)

    hit = pool.acquire_prefix(2, prompt)
    assert list(hit.pages) == p1[:2] and hit.tokens == 8
    assert hit.cow_page == p1[2] and hit.cow_tokens == 2
    assert hit.hit_tokens == 10
    assert pool.owned(2) == p1[:2]
    assert pool.refcount(p1[0]) == 2 and pool.refcount(p1[2]) == 1

    # shorter probe: only the first full page matches
    short = pool.acquire_prefix(3, prompt[:4])
    assert list(short.pages) == p1[:1] and short.cow_tokens == 0
    pool.free(3)

    # donor retires: shared pages stay live, the partial parks as cached
    pool.free(1)
    assert pool.refcount(p1[0]) == 1 and pool.refcount(p1[2]) == 0
    assert pool.cached_pages == 1 and pool.used_pages == 2
    pool.free(2)
    assert pool.used_pages == 0 and pool.cached_pages == 3
    assert pool.available_pages == pool.num_pages

    # revive from cached, then force eviction of everything
    again = pool.acquire_prefix(4, prompt)
    assert again.hit_tokens == 10 and pool.used_pages == 2
    pool.free(4)
    assert pool.alloc(pool.num_pages, owner=5) is not None
    assert pool.evictions == 3 and pool.cached_pages == 0
    miss = pool.acquire_prefix(6, prompt)
    assert miss.hit_tokens == 0 and pool.prefix_misses == 1


def test_prefix_pool_eviction_invalidates_subtree():
    """Evicting a chain's root must drop every descendant key: a recycled
    page id republished at another depth would otherwise make stale child
    keys hittable with KV from a different prefix/position."""
    pool = KVPool(num_pages=4, page_size=2)
    prompt = [7, 8, 9, 10, 11, 12]                   # 3 full pages
    pool.alloc(3, owner=1)
    pool.publish_prefix(1, prompt)
    pool.free(1)
    assert pool.cached_pages == 3
    pool._evict_one()                                # root leaves the index
    assert pool.evictions == 1
    hit = pool.acquire_prefix(2, prompt)
    assert hit.hit_tokens == 0, "descendant keys must die with their root"
    assert pool.utilization()["indexed_pages"] == 0


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 999), min_size=1, max_size=80))
def test_prefix_pool_invariants_under_sharing(ops_seq):
    """Random acquire_prefix/publish/alloc/free interleavings over a tiny
    token alphabet (maximal sharing pressure): conservation, refcount ==
    number of owners mapping the page, indexed pages never free, all-or-
    nothing grants — after EVERY operation."""
    N = 13
    pool = KVPool(num_pages=N, page_size=4)
    prompts = {}                                     # uid -> token list
    next_uid = 0
    for op in ops_seq:
        kind = op % 4
        if kind == 0 and prompts:                    # retire a random owner
            uid = list(prompts)[op % len(prompts)]
            del prompts[uid]
            if pool.owned(uid):
                pool.free(uid)
                with pytest.raises(KeyError):
                    pool.free(uid)
        elif kind == 1 and prompts:                  # publish a random owner
            uid = list(prompts)[op % len(prompts)]
            pool.publish_prefix(uid, prompts[uid])
        else:                                        # admit: acquire + ensure
            L = (op // 7) % 11 + 1
            prompt = [(op + 3 * j) % 3 for j in range(L)]
            uid = next_uid
            next_uid += 1
            hit = pool.acquire_prefix(uid, prompt)
            assert hit.tokens == len(hit.pages) * 4
            assert hit.cow_tokens < 4
            got = pool.ensure(uid, pool.pages_for(len(prompt)))
            if got is None:                          # admission rollback
                if pool.owned(uid):
                    pool.free(uid)
            else:
                prompts[uid] = prompt

        # invariants after EVERY op
        holders = {}
        for uid in pool.owners():
            pages = pool.owned(uid)
            assert len(pages) == len(set(pages)), "page twice in one lane"
            for p in pages:
                holders[p] = holders.get(p, 0) + 1
        for p, n in holders.items():
            assert pool.refcount(p) == n, "refcount != number of holders"
            assert 1 <= p <= N
        live = len(holders)
        assert pool.used_pages == live
        assert pool.free_pages + pool.cached_pages + live == N, "leak"
        assert pool.available_pages == pool.free_pages + pool.cached_pages
        for page in list(pool._page_key):
            assert page not in pool._free_set, "indexed page on free list"
        assert pool.prefix_hits + pool.prefix_misses == pool.prefix_lookups


def test_prefix_pool_watermark_edges_with_evictable_headroom():
    """can_alloc/ensure count evictable cached pages as free headroom, and
    alloc reclaims them lazily (oldest first) only when strictly-free pages
    cannot cover the grant."""
    pool = KVPool(num_pages=6, page_size=4)
    pool.alloc(4, owner=1)
    pool.publish_prefix(1, list(range(16)))          # 4 full pages
    pool.free(1)
    assert pool.free_pages == 2 and pool.cached_pages == 4
    assert pool.can_alloc(6) and not pool.can_alloc(6, watermark=1)
    assert pool.can_alloc(5, watermark=1)
    got = pool.ensure(2, 3)                          # 2 free + 1 eviction
    assert got is not None and len(got) == 3
    assert pool.evictions == 1 and pool.cached_pages == 3
    # the evicted page was the LRU root -> whole chain left the index
    assert pool.acquire_prefix(3, list(range(16))).hit_tokens == 0
    assert pool.ensure(2, 3) == []                   # already provisioned
    assert pool.ensure(2, 10) is None, "beyond free+cached must fail"
    assert pool.failed_allocs == 1


# ---------------------------------------------------------------------------
# 2) spec-level: shared prefix pages == cold prefill, greedy + sampled
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_shared_pages_match_cold_stream(temperature):
    """Two lanes with an identical page-aligned prompt: run A with both
    lanes cold-prefilled, run B with lane 1 splicing lane 0's prefix pages
    (table splice, no copy).  Same PRNG keys => accept counts and committed
    tokens must be bit-identical — under greedy decoding AND Leviathan
    rejection sampling."""
    cfg = tiny_cfg("vicuna-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    K = cfg.dvi.k_spec
    B, Tp, ps, mps = 2, 9, 4, 16                     # prompt[:-1] = 2 pages
    prompt = jax.random.randint(jax.random.PRNGKey(1), (Tp,), 2,
                                cfg.vocab_size)
    prompts = jnp.tile(prompt[None, :], (B, 1))

    def grow(cache, pool, lens):
        for b in range(B):
            need = pages_for(lens[b], ps)
            if need > len(pool.owned(b)):
                assert pool.ensure(b, need) is not None
                row = np.full(mps, -1, np.int32)
                owned = pool.owned(b)
                row[:len(owned)] = owned
                cache = tfm.map_slot_pages(cache, jnp.int32(b),
                                           jnp.asarray(row))
        return cache

    def setup(shared):
        pool = KVPool(num_pages=2 * mps, page_size=ps)
        cache = model.init_paged_cache(B, pool.num_pages, ps, mps)
        cache = grow(cache, pool, [Tp - 1 + K + 2] * B)
        _, pc, _ = model.prefill(params, prompts[:1, :-1], max_len=Tp - 1)
        cache = tfm.insert_slot(cfg, cache, pc, jnp.int32(0))
        if shared:
            # lane 1 = lane 0's prefix pages + its own pages for the tail
            pool.free(1)
            pool.publish_prefix(0, [int(t) for t in prompt[:-1]])
            hit = pool.acquire_prefix(1, [int(t) for t in prompt[:-1]])
            assert hit.tokens == Tp - 1 and hit.cow_tokens == 0
            assert pool.ensure(1, pages_for(Tp - 1 + K + 2, ps)) is not None
            row = np.full(mps, -1, np.int32)
            owned = pool.owned(1)
            row[:len(owned)] = owned
            assert owned[:2] == pool.owned(0)[:2], "pages not shared"
            cache = tfm.map_slot_pages(cache, jnp.int32(1), jnp.asarray(row))
            cache = tfm.insert_slot(cfg, cache, None, jnp.int32(1),
                                    shared_len=Tp - 1)
        else:
            _, pc, _ = model.prefill(params, prompts[1:, :-1], max_len=Tp - 1)
            cache = tfm.insert_slot(cfg, cache, pc, jnp.int32(1))
        return pool, cache

    streams = {}
    for shared in (False, True):
        pool, cache = setup(shared)
        pending = prompts[:, -1]
        key = jax.random.PRNGKey(42)
        lens, out = [Tp - 1] * B, [[], []]
        for _ in range(5):
            cache = grow(cache, pool, [t + K + 2 for t in lens])
            blk = spec.spec_block_step(model, params, dvi, pending, cache,
                                       temperature=temperature, key=key)
            pending, cache, key = blk.pending, blk.cache, blk.key
            for b in range(B):
                out[b] += np.asarray(
                    blk.commit_vec[b, :int(blk.accept[b])]).tolist()
            lens = [t + int(blk.accept[b]) for b, t in enumerate(lens)]
        streams[shared] = out
    assert streams[True] == streams[False], (
        f"sharing changed the committed stream (temperature={temperature})")


def test_insert_slot_table_splice_requires_paged():
    cfg = tiny_cfg("vicuna-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    _, cache, _ = model.prefill(params, jnp.ones((2, 6), jnp.int32),
                                max_len=16)
    with pytest.raises(NotImplementedError):
        tfm.insert_slot(cfg, cache, None, jnp.int32(0), shared_len=4)
    with pytest.raises(ValueError):
        paged = model.init_paged_cache(2, 8, 4, 4)
        tfm.insert_slot(cfg, paged, None, jnp.int32(0))


# ---------------------------------------------------------------------------
# 3) engine end-to-end: warm == cold, COW, preemption, leak-free drain
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def backbone():
    cfg = tiny_cfg("vicuna-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _shared_prefix_requests(cfg, n, sys_len=10, seed=7):
    """n requests from 2 tenants: each tenant's requests share a system
    prompt of `sys_len` tokens followed by a short unique tail."""
    rng = np.random.default_rng(seed)
    tenants = [rng.integers(2, cfg.vocab_size, sys_len).astype(np.int32)
               for _ in range(2)]
    reqs = []
    for i in range(n):
        tail = rng.integers(2, cfg.vocab_size, 3 + i % 3).astype(np.int32)
        reqs.append(Request(uid=i, prompt=np.concatenate(
            [tenants[i % 2], tail]), max_new=8))
    return reqs


def _run_engine(model, params, reqs, **kw):
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    eng = ServingEngine(model, params, state, scheduler="continuous", **kw)
    for r in reqs:
        eng.submit(r)
    outs = eng.run(max_steps=3000)
    assert len(outs) == len(reqs) and not eng.busy
    return eng, {o.uid: o.gen_tokens.tolist() for o in outs}


def test_engine_prefix_cache_lossless(backbone):
    """Multi-tenant shared system prompts through the full engine: the warm
    run must emit byte-identical streams to the cold run, save real prefill
    work, and drain leak-free (every refcount back to baseline)."""
    cfg, model, params = backbone
    reqs = _shared_prefix_requests(cfg, 8)
    kw = dict(num_slots=3, max_new=8, cache_len=40, kv_pages=30,
              kv_page_size=4, prefill_chunk=4)
    eng_c, out_c = _run_engine(model, params, reqs, **kw)
    eng_w, out_w = _run_engine(model, params, reqs, prefix_cache=True, **kw)
    assert out_w == out_c, "prefix cache changed a committed stream"

    kv = eng_w.kv_stats()
    assert kv["prefix_hits"] > 0 and kv["prefix_hit_tokens"] > 0
    assert kv["prefix_hits"] + kv["prefix_misses"] == kv["prefix_lookups"]
    # hit tokens are never prefilled: chunked-prefill work must shrink
    assert eng_w.stats["prefill_tokens"] < eng_c.stats["prefill_tokens"]
    assert kv["prefix_hit_tokens"] >= kv["prefix_hits"]
    # leak-free drain: nothing live, every page free or evictable-cached
    assert kv["used_pages"] == 0
    assert kv["free_pages"] + kv["cached_pages"] == kv["num_pages"]
    assert eng_c.stats["prefix_lookups"] == 0, "cold run must not probe"


def test_engine_prefix_cow_path(backbone):
    """A short-tail request publishes a PARTIAL page; the next request with
    a longer tail must COW it (cow_copies >= 1) and still match cold."""
    cfg, model, params = backbone
    rng = np.random.default_rng(11)
    sysp = rng.integers(2, cfg.vocab_size, 10).astype(np.int32)
    first = Request(uid=0, prompt=np.concatenate(
        [sysp, rng.integers(2, cfg.vocab_size, 1).astype(np.int32)]),
        max_new=6)                                   # prompt[:-1] = 10 toks
    second = Request(uid=1, prompt=np.concatenate(
        [sysp, rng.integers(2, cfg.vocab_size, 4).astype(np.int32)]),
        max_new=6)
    kw = dict(num_slots=2, max_new=6, cache_len=40, kv_pages=24,
              kv_page_size=4, prefill_chunk=4)

    def run(**extra):
        state = online.init_trainer(model, jax.random.PRNGKey(3))
        eng = ServingEngine(model, params, state, scheduler="continuous",
                            **kw, **extra)
        eng.submit(first)
        outs = eng.run(max_steps=1000)               # donor fully retires,
        eng.submit(second)                           # THEN the COW consumer
        outs += eng.run(max_steps=1000)
        assert len(outs) == 2 and not eng.busy
        return eng, {o.uid: o.gen_tokens.tolist() for o in outs}

    eng_c, out_c = run()
    eng_w, out_w = run(prefix_cache=True)
    assert out_w == out_c, "COW path changed a committed stream"
    assert eng_w.stats["prefix_cow_copies"] >= 1, "partial hit never COWed"
    kv = eng_w.kv_stats()
    assert kv["prefix_hit_tokens"] >= 10            # 2 full pages + 2 COW
    assert kv["used_pages"] == 0


def test_engine_prefix_cache_preemption_lossless(backbone):
    """Pool tight enough to force preemption while prefixes are shared:
    replayed lanes re-acquire warm and every stream still equals the
    greedy AR reference; refcounts return to baseline after drain."""
    cfg, model, params = backbone
    reqs = _shared_prefix_requests(cfg, 6, sys_len=8, seed=5)
    eng, out = _run_engine(model, params, reqs, num_slots=3, max_new=8,
                           cache_len=40, kv_pages=14, kv_page_size=4,
                           prefill_chunk=4, prefix_cache=True)
    for req in reqs:
        r = spec.ar_generate(model, params, jnp.asarray(req.prompt)[None, :],
                             req.max_new)
        gen = np.asarray(
            r.tokens[0, len(req.prompt):int(r.lengths[0])]).tolist()
        ref = []
        for t in gen[:req.max_new]:
            ref.append(int(t))
            if t == 1:
                break
        assert out[req.uid] == ref, f"uid {req.uid}: {out[req.uid]} != {ref}"
    kv = eng.kv_stats()
    assert kv["preemptions"] > 0, "pool not tight enough to preempt"
    assert kv["used_pages"] == 0
    assert kv["free_pages"] + kv["cached_pages"] == kv["num_pages"]


def test_engine_prefix_cache_rejects_bad_config(backbone):
    cfg, model, params = backbone
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    with pytest.raises(ValueError):                  # needs a paged pool
        ServingEngine(model, params, state, scheduler="continuous",
                      cache_len=40, prefix_cache=True)
    with pytest.raises(ValueError):                  # needs chunked prefill
        ServingEngine(model, params, state, scheduler="continuous",
                      cache_len=40, kv_pages=20, kv_page_size=4,
                      prefix_cache=True)
