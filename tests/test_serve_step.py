"""serve_step (the decode dry-run workload) is the same speculative block
the generation engine runs: chained serve_steps must reproduce the greedy
AR continuation exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import ARCHS, make_aux
from repro.core import lora, spec


@pytest.mark.parametrize("name", ["vicuna-7b", "mamba2-370m",
                                  "llama4-scout-17b-a16e", "deepseek-v3-671b"])
def test_chained_serve_steps_lossless(tiny_models, name):
    cfg, model, params = tiny_models(name)
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    B, Tp = 2, 8
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, Tp), 2,
                                 cfg.vocab_size)
    aux = make_aux(cfg, B)
    r_ar = spec.ar_generate(model, params, prompts, 20, aux_inputs=aux)

    _, cache, _ = model.prefill(params, prompts[:, :-1], aux, max_len=64)
    pending = prompts[:, -1]
    emitted = [[] for _ in range(B)]
    for _ in range(8):
        pending, commit_vec, accept, cache = spec.serve_step(
            model, params, dvi, pending, cache)
        for b in range(B):
            emitted[b].extend(np.asarray(commit_vec[b, :int(accept[b])]).tolist())
    for b in range(B):
        ref = np.asarray(r_ar.tokens[b, Tp:int(r_ar.lengths[b])]).tolist()
        n = min(len(ref), len(emitted[b]))
        assert emitted[b][:n] == ref[:n], f"{name} seq {b} diverged"


def test_serve_step_accept_range(tiny_models):
    cfg, model, params = tiny_models("vicuna-7b")
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (3, 8), 2,
                                 cfg.vocab_size)
    _, cache, _ = model.prefill(params, prompts[:, :-1], max_len=64)
    pending, commit_vec, accept, cache = spec.serve_step(
        model, params, dvi, prompts[:, -1], cache)
    K = cfg.dvi.k_spec
    assert bool(jnp.all((accept >= 1) & (accept <= K + 1)))
    assert commit_vec.shape == (3, K + 1)
    assert bool(jnp.all(cache["lengths"] == 7 + accept))
