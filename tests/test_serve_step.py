"""The unified speculative block-step (`spec_block_step`) is the ONE owner of
draft -> verify -> commit: chained block-steps must reproduce the greedy AR
continuation exactly, and composing it in a loop must reproduce
`speculative_generate`'s committed stream token-for-token (greedy AND
rejection-sampling paths)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import ARCHS, make_aux, tiny_cfg
from repro.core import lora, spec
from repro.models import transformer as tfm
from repro.models.model import build_model


@pytest.mark.parametrize("name", ["vicuna-7b", "mamba2-370m",
                                  "llama4-scout-17b-a16e", "deepseek-v3-671b"])
def test_chained_block_steps_lossless(tiny_models, name):
    cfg, model, params = tiny_models(name)
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    B, Tp = 2, 8
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, Tp), 2,
                                 cfg.vocab_size)
    aux = make_aux(cfg, B)
    r_ar = spec.ar_generate(model, params, prompts, 20, aux_inputs=aux)

    _, cache, _ = model.prefill(params, prompts[:, :-1], aux, max_len=64)
    pending = prompts[:, -1]
    emitted = [[] for _ in range(B)]
    for _ in range(8):
        blk = spec.spec_block_step(model, params, dvi, pending, cache)
        pending, cache = blk.pending, blk.cache
        for b in range(B):
            emitted[b].extend(
                np.asarray(blk.commit_vec[b, :int(blk.accept[b])]).tolist())
    for b in range(B):
        ref = np.asarray(r_ar.tokens[b, Tp:int(r_ar.lengths[b])]).tolist()
        n = min(len(ref), len(emitted[b]))
        assert emitted[b][:n] == ref[:n], f"{name} seq {b} diverged"


def test_block_step_accept_range_and_done_mask(tiny_models):
    cfg, model, params = tiny_models("vicuna-7b")
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (3, 8), 2,
                                 cfg.vocab_size)
    _, cache, _ = model.prefill(params, prompts[:, :-1], max_len=64)
    K = cfg.dvi.k_spec
    done = jnp.array([False, True, False])
    blk = spec.spec_block_step(model, params, dvi, prompts[:, -1], cache,
                               done=done)
    assert bool(jnp.all((blk.accept >= 1) | done))
    assert bool(jnp.all(blk.accept <= K + 1))
    # masked lane: nothing committed, pending passed through, length frozen
    assert int(blk.accept[1]) == 0
    assert int(blk.pending[1]) == int(prompts[1, -1])
    assert int(blk.cache["lengths"][1]) == 7
    assert bool(jnp.all(blk.cache["lengths"][jnp.array([0, 2])]
                        == 7 + blk.accept[jnp.array([0, 2])]))
    assert blk.commit_vec.shape == (3, K + 1)


def test_serve_step_wrapper_delegates(tiny_models):
    """Back-compat wrapper (used by the decode dry-run) is a pure delegate."""
    cfg, model, params = tiny_models("vicuna-7b")
    dvi = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (3, 8), 2,
                                 cfg.vocab_size)
    _, cache, _ = model.prefill(params, prompts[:, :-1], max_len=64)
    pending, commit_vec, accept, cache2 = spec.serve_step(
        model, params, dvi, prompts[:, -1], cache)
    blk = spec.spec_block_step(model, params, dvi, prompts[:, -1], cache)
    assert bool(jnp.all(pending == blk.pending))
    assert bool(jnp.all(commit_vec == blk.commit_vec))
    assert bool(jnp.all(accept == blk.accept))


def _compose_blocks(model, params, dvi, prompts, max_new, temperature=0.0,
                    key=None, eos_id=1):
    """Re-derive speculative_generate's stream by looping spec_block_step
    with host-side output/EOS bookkeeping."""
    cfg = model.cfg
    K = cfg.dvi.k_spec
    B, Tp = prompts.shape
    total = Tp + max_new + K + 2
    _, cache, _ = model.prefill(params, prompts[:, :Tp - 1],
                                max_len=total + tfm.RING_SLACK)
    pending = prompts[:, Tp - 1]
    key = key if key is not None else jax.random.PRNGKey(0)
    out = np.zeros((B, total), np.int32)
    out[:, :Tp] = np.asarray(prompts)
    out_len = np.full((B,), Tp)
    done = np.zeros((B,), bool)
    while not done.all():
        blk = spec.spec_block_step(model, params, dvi, pending, cache,
                                   done=jnp.asarray(done),
                                   temperature=temperature, key=key)
        pending, cache, key = blk.pending, blk.cache, blk.key
        acc = np.asarray(blk.accept)
        cv = np.asarray(blk.commit_vec)
        for b in range(B):
            a = int(acc[b])
            out[b, out_len[b]:out_len[b] + a] = cv[b, :a]
            if (cv[b, :a] == eos_id).any():
                done[b] = True
            out_len[b] += a
            if out_len[b] >= Tp + max_new:
                done[b] = True
    return out, out_len


@pytest.mark.slow
@given(st.integers(0, 2 ** 16), st.sampled_from([0.0, 0.8]))
@settings(max_examples=6, deadline=None)
def test_block_step_composition_matches_generate(seed, temperature):
    """Property: spec_block_step composed in a loop reproduces
    speculative_generate token-for-token — greedy and rejection-sampling."""
    cfg = tiny_cfg("vicuna-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed % 97))
    dvi = lora.init_draft_params(jax.random.PRNGKey(seed % 31), cfg)
    dvi = dict(dvi, B=jax.random.normal(jax.random.PRNGKey(seed),
                                        dvi["B"].shape) * 0.05)
    prompts = jax.random.randint(jax.random.PRNGKey(seed), (2, 6), 2,
                                 cfg.vocab_size)
    key = jax.random.PRNGKey(seed + 1)
    ref = spec.speculative_generate(model, params, dvi, prompts, 12,
                                    temperature=temperature, key=key)
    out, out_len = _compose_blocks(model, params, dvi, prompts, 12,
                                   temperature=temperature, key=key)
    np.testing.assert_array_equal(out_len, np.asarray(ref.lengths))
    cap = 6 + 12          # done-lane writes may clamp-scribble past Tp+max_new
    for b in range(2):
        n = min(int(out_len[b]), cap)
        np.testing.assert_array_equal(out[b, :n],
                                      np.asarray(ref.tokens[b, :n]))
