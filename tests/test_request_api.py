"""submit_request/RequestHandle engine surface: shim equivalence, delta
streaming, lifecycle timestamps, backpressure, and the lifecycle-counter
reconciliation the metrics schema gate enforces."""
import os
import sys

import jax
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core import online
from repro.models.model import build_model
from repro.serving import QueueFull, Request, ServingEngine

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "scripts"))
import check_metrics_schema  # noqa: E402


@pytest.fixture(scope="module")
def backbone():
    cfg = tiny_cfg("vicuna-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reqs(cfg, n, seed=0, max_new=8, tenant=None, plen=12):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(2, cfg.vocab_size, plen,
                                        dtype=np.int64).astype(np.int32),
                    max_new=max_new,
                    tenant=tenant(i) if tenant else "default")
            for i in range(n)]


def _engine(model, params, **kw):
    state = online.init_trainer(model, jax.random.PRNGKey(3))
    kw.setdefault("scheduler", "continuous")
    kw.setdefault("num_slots", 3)
    kw.setdefault("max_new", 16)
    kw.setdefault("buckets", (16,))
    return ServingEngine(model, params, state, **kw)


def test_submit_shim_warns_and_streams_identically(backbone):
    cfg, model, params = backbone
    reqs = _reqs(cfg, 5, seed=1)

    eng_new = _engine(model, params)
    handles = [eng_new.submit_request(r) for r in reqs]
    new_outs = {c.uid: c.gen_tokens.tolist() for c in eng_new.run(500)}

    eng_old = _engine(model, params)
    with pytest.warns(DeprecationWarning, match="submit_request"):
        for r in reqs:
            eng_old.submit(r)
    old_outs = {c.uid: c.gen_tokens.tolist() for c in eng_old.run(500)}

    assert old_outs == new_outs          # the shim changes nothing downstream
    for r in reqs:                       # and the handle saw the same stream
        assert handles[r.uid].tokens() == new_outs[r.uid]


def test_deltas_accumulate_to_completion(backbone):
    import threading

    cfg, model, params = backbone
    eng = _engine(model, params)
    reqs = _reqs(cfg, 4, seed=2)
    hs = [eng.submit_request(r) for r in reqs]
    chunks = {h.uid: [] for h in hs}

    def consume(h):                      # one consumer thread per handle,
        for ch in h.deltas(timeout=120.0):   # as the HTTP layer does
            chunks[h.uid].append(ch)

    threads = [threading.Thread(target=consume, args=(h,)) for h in hs]
    for t in threads:
        t.start()
    outs = {c.uid: c for c in eng.run(500)}
    for t in threads:
        t.join(timeout=120.0)
        assert not t.is_alive()
    for h in hs:
        got = [t for ch in chunks[h.uid] for t in ch]
        assert got == outs[h.uid].gen_tokens.tolist()
        assert len(chunks[h.uid]) >= 2   # streamed, not one lump
        assert h.result(timeout=1.0) is outs[h.uid]


def test_lifecycle_timestamps_ordered(backbone):
    cfg, model, params = backbone
    eng = _engine(model, params, num_slots=2)
    hs = [eng.submit_request(r) for r in _reqs(cfg, 4, seed=3)]
    eng.run(500)
    for h in hs:
        assert (h.t_submit <= h.t_admit <= h.t_prefill_done
                <= h.t_first_token <= h.t_done)
        t = h.timings()
        assert all(v is not None and v >= 0 for v in t.values()), t
        assert t["e2e_s"] == pytest.approx(
            t["queue_wait_s"] + t["prefill_s"] + t["decode_s"])


def test_queue_full_rejects_explicitly(backbone):
    cfg, model, params = backbone
    eng = _engine(model, params, max_queue=2)
    reqs = _reqs(cfg, 5, seed=4, max_new=4)
    accepted, rejected = [], []
    for r in reqs:                       # no stepping: queue can't drain
        try:
            accepted.append(eng.submit_request(r))
        except QueueFull as e:
            rejected.append(e.handle)
    assert len(accepted) == 2 and len(rejected) == 3
    for h in rejected:                   # rejection is a terminal outcome,
        assert h.outcome == "rejected"   # not an invisible drop
        assert h.result(timeout=1.0) is None
    eng.run(500)
    assert all(h.outcome == "completed" for h in accepted)
    assert eng.stats["submitted"] == 5
    assert eng.stats["rejected"] == 3
    assert eng.stats["requests"] == 2


def test_lifecycle_counters_reconcile_in_schema_gate(backbone):
    cfg, model, params = backbone
    eng = _engine(model, params, max_queue=3,
                  tenant_weights={"gold": 2.0, "free": 1.0})
    reqs = _reqs(cfg, 6, seed=5, max_new=4,
                 tenant=lambda i: "gold" if i % 2 else "free")
    hs = []
    for r in reqs:
        try:
            hs.append(eng.submit_request(r))
        except QueueFull:
            pass
        if len(hs) == 2:
            eng.step()                   # drain a little so most get in
    hs[0].cancel()
    eng.run(500)
    snap = eng.metrics_snapshot()
    errs = check_metrics_schema.check_snapshot(snap, "test")
    assert errs == [], errs
    by_tenant = snap["dvi_serving_requests_by_tenant"]["values"]
    assert sum(by_tenant.values()) == eng.stats["submitted"]
    assert set(by_tenant) <= {"gold", "free"}
    # drained: submitted fully accounted
    assert (eng.stats["submitted"] == eng.stats["requests"]
            + eng.stats["cancelled"] + eng.stats["rejected"])


def test_prometheus_round_trip_carries_labels(backbone):
    cfg, model, params = backbone
    from repro.serving.telemetry import parse_prometheus_text
    eng = _engine(model, params)
    for r in _reqs(cfg, 3, seed=6, max_new=4,
                   tenant=lambda i: f"t{i}"):
        eng.submit_request(r)
    eng.run(500)
    back = parse_prometheus_text(eng.render_prometheus())
    vals = back["dvi_serving_requests_by_tenant"]["values"]
    assert vals == {"t0": 1, "t1": 1, "t2": 1}
    assert back["dvi_serving_requests_by_tenant"]["value"] == 3
    assert back["dvi_serving_ttft_seconds"]["count"] == 3
    assert back["dvi_serving_queue_wait_seconds"]["count"] == 3
