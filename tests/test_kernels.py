"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# interpret-mode Pallas is slow on CPU; CI runs these in their own
# kernels-interpret job (`-m kernels`) so the tier-1 matrix stays fast
pytestmark = pytest.mark.kernels

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas as decode_attention
from repro.kernels.lora_logits import lora_logits
from repro.kernels.paged_decode_attention import paged_decode_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.verify_argmax import verify_argmax

I = dict(interpret=True)


@pytest.mark.parametrize("T,d,V,bt,bv", [
    (5, 64, 500, 16, 128), (128, 128, 2048, 64, 512), (33, 256, 1000, 8, 256),
    (1, 32, 128, 8, 128), (64, 64, 4096, 64, 1024),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_verify_argmax(T, d, V, bt, bv, dtype):
    h = jax.random.normal(jax.random.PRNGKey(T + V), (T, d), dtype)
    w = jax.random.normal(jax.random.PRNGKey(V), (d, V), dtype)
    arg, mx = verify_argmax(h, w, block_t=bt, block_v=bv, **I)
    arg_ref, mx_ref = ref.ref_verify_argmax(h, w)
    np.testing.assert_array_equal(np.asarray(arg), np.asarray(arg_ref))
    np.testing.assert_allclose(np.asarray(mx), np.asarray(mx_ref),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("T,d,V,r", [(5, 64, 500, 8), (64, 128, 1024, 16),
                                     (17, 64, 300, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_logits(T, d, V, r, dtype):
    h = jax.random.normal(jax.random.PRNGKey(0), (T, d), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (d, V), dtype)
    a = jax.random.normal(jax.random.PRNGKey(2), (d, r), dtype)
    b = jax.random.normal(jax.random.PRNGKey(3), (r, V), dtype)
    out = lora_logits(h, w, a, b, 2.0, block_t=16, block_v=256, **I)
    expect = ref.ref_lora_logits(h, w, a, b, 2.0)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("B,H,KV,hd,S,bs", [
    (2, 8, 2, 32, 100, 32), (3, 16, 16, 64, 64, 64), (1, 4, 1, 128, 300, 128),
    (2, 8, 8, 64, 33, 16),
])
def test_decode_attention(B, H, KV, hd, S, bs):
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd))
    lens = jax.random.randint(jax.random.PRNGKey(3), (B,), 1, S + 1)
    out = decode_attention(q, k, v, lens, block_s=bs, **I)
    expect = ref.ref_decode_attention(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


@pytest.mark.parametrize("B,T,H,hd,ds,Q", [
    (2, 64, 4, 16, 32, 16), (1, 128, 8, 64, 128, 64), (2, 32, 2, 8, 16, 32),
])
def test_ssd_scan(B, T, H, hd, ds, Q):
    xh = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, hd))
    Bc = jax.random.normal(jax.random.PRNGKey(1), (B, T, 1, ds)) * 0.5
    Cc = jax.random.normal(jax.random.PRNGKey(2), (B, T, 1, ds)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(3), (B, T, H)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(4), (H,)) * 0.3)
    y, h = ssd_scan(xh, Bc, Cc, dt, A, chunk=Q, **I)
    y_ref, h_ref = ref.ref_ssd_scan(xh, Bc, Cc, dt, A, Q)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-4)


def _paged_setup(key, B, KV, hd, ps, pages_per_lane, holes=False):
    """Random pooled pages + block tables; returns (k_pages, v_pages,
    lengths, tbl).  Lanes own disjoint pages in shuffled physical order;
    `holes` leaves trailing table entries unmapped (-1)."""
    P = B * pages_per_lane + 1                     # + null page 0
    ks = jax.random.split(key, 4)
    kp = jax.random.normal(ks[0], (P, ps, KV, hd))
    vp = jax.random.normal(ks[1], (P, ps, KV, hd))
    perm = np.random.default_rng(int(ks[2][0])).permutation(P - 1) + 1
    MPS = pages_per_lane + (2 if holes else 0)
    tbl = np.full((B, MPS), -1, np.int32)
    for b in range(B):
        tbl[b, :pages_per_lane] = perm[b * pages_per_lane:
                                       (b + 1) * pages_per_lane]
    cap = pages_per_lane * ps
    lens = jax.random.randint(ks[3], (B,), 1, cap + 1)
    return kp, vp, lens, jnp.asarray(tbl)


@pytest.mark.parametrize("B,H,KV,hd,ps,ppl", [
    (2, 8, 2, 32, 8, 4), (3, 16, 16, 64, 16, 2), (1, 4, 1, 128, 4, 7),
])
@pytest.mark.parametrize("holes", [False, True])
def test_paged_decode_attention(B, H, KV, hd, ps, ppl, holes):
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, hd))
    kp, vp, lens, tbl = _paged_setup(jax.random.PRNGKey(B * H), B, KV, hd,
                                     ps, ppl, holes)
    out = paged_decode_attention(q, kp, vp, lens, tbl, **I)
    expect = ref.ref_paged_decode_attention(q, kp, vp, lens, tbl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


def test_paged_early_out_ragged_lengths():
    """Per-lane page-count early-out: lanes spanning 1 slot up to the full
    mapped capacity (ragged, incl. page-boundary lengths) must match the
    full-sweep oracle bit-for-bit — the skipped pages were all masked."""
    B, H, KV, hd, ps, ppl = 4, 8, 4, 16, 8, 6
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, hd))
    kp, vp, _, tbl = _paged_setup(jax.random.PRNGKey(2), B, KV, hd, ps, ppl)
    # 1 slot, page-boundary, mid-page, full capacity
    lens = jnp.array([1, ps, 2 * ps + 3, ppl * ps])
    out = paged_decode_attention(q, kp, vp, lens, tbl, **I)
    expect = ref.ref_paged_decode_attention(q, kp, vp, lens, tbl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


def test_paged_explicit_page_counts_matches_oracle():
    """An explicit page_counts SMALLER than the length coverage trims the
    attended window; kernel and oracle must agree on the trimmed result."""
    B, H, KV, hd, ps, ppl = 2, 8, 2, 32, 8, 4
    q = jax.random.normal(jax.random.PRNGKey(1), (B, H, hd))
    kp, vp, _, tbl = _paged_setup(jax.random.PRNGKey(3), B, KV, hd, ps, ppl)
    lens = jnp.full((B,), ppl * ps)                 # full lanes...
    pc = jnp.array([1, 3], jnp.int32)               # ...but trimmed sweeps
    out = paged_decode_attention(q, kp, vp, lens, tbl, page_counts=pc, **I)
    expect = ref.ref_paged_decode_attention(q, kp, vp, lens, tbl,
                                            page_counts=pc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)
    # and forcing the full sweep on short lanes changes nothing
    short = jnp.full((B,), ps // 2)
    full = paged_decode_attention(q, kp, vp, short, tbl,
                                  page_counts=jnp.full((B,), ppl, jnp.int32),
                                  **I)
    trim = paged_decode_attention(q, kp, vp, short, tbl, **I)
    np.testing.assert_allclose(np.asarray(trim), np.asarray(full), atol=2e-5)


def test_paged_matches_contiguous_ref():
    """A paged cache whose pages are laid out in logical order must attend
    identically to the same KV stored contiguously."""
    B, H, KV, hd, ps, ppl = 2, 8, 4, 32, 8, 3
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, hd))
    kp, vp, lens, tbl = _paged_setup(jax.random.PRNGKey(9), B, KV, hd, ps, ppl)
    # materialize each lane's logical view as a contiguous cache
    flat = lambda c: np.asarray(c).reshape(-1, KV, hd)
    tbl_np = np.asarray(tbl)
    idx = tbl_np[:, np.arange(ppl * ps) // ps] * ps + np.arange(ppl * ps) % ps
    k_c = jnp.asarray(flat(kp)[idx])                 # (B, S, KV, hd)
    v_c = jnp.asarray(flat(vp)[idx])
    out_p = paged_decode_attention(q, kp, vp, lens, tbl, **I)
    out_c = ref.ref_decode_attention(q, k_c, v_c, lens)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_c), atol=2e-5)


def test_ops_wrappers_jit():
    """ops.py jit'd wrappers dispatch to interpret mode on CPU, and the
    decode dispatch point agrees across ref/pallas/paged implementations."""
    from repro.kernels import ops
    h = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 256))
    arg, mx = ops.verify_argmax(h, w, block_t=8, block_v=128)
    arg_ref, _ = ref.ref_verify_argmax(h, w)
    np.testing.assert_array_equal(np.asarray(arg), np.asarray(arg_ref))

    q = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 32))
    k = jax.random.normal(jax.random.PRNGKey(3), (2, 64, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(4), (2, 64, 2, 32))
    lens = jnp.array([50, 3])
    np.testing.assert_allclose(
        np.asarray(ops.decode_attention(q, k, v, lens, block_s=16)),
        np.asarray(ops.decode_attention(q, k, v, lens, impl="ref")), atol=2e-5)
    kp, vp, plens, tbl = _paged_setup(jax.random.PRNGKey(5), 2, 2, 32, 8, 4)
    np.testing.assert_allclose(
        np.asarray(ops.paged_decode_attention(q, kp, vp, plens, tbl)),
        np.asarray(ops.paged_decode_attention(q, kp, vp, plens, tbl,
                                              impl="ref")), atol=2e-5)
