"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.lora_logits import lora_logits
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.verify_argmax import verify_argmax

I = dict(interpret=True)


@pytest.mark.parametrize("T,d,V,bt,bv", [
    (5, 64, 500, 16, 128), (128, 128, 2048, 64, 512), (33, 256, 1000, 8, 256),
    (1, 32, 128, 8, 128), (64, 64, 4096, 64, 1024),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_verify_argmax(T, d, V, bt, bv, dtype):
    h = jax.random.normal(jax.random.PRNGKey(T + V), (T, d), dtype)
    w = jax.random.normal(jax.random.PRNGKey(V), (d, V), dtype)
    arg, mx = verify_argmax(h, w, block_t=bt, block_v=bv, **I)
    arg_ref, mx_ref = ref.ref_verify_argmax(h, w)
    np.testing.assert_array_equal(np.asarray(arg), np.asarray(arg_ref))
    np.testing.assert_allclose(np.asarray(mx), np.asarray(mx_ref),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("T,d,V,r", [(5, 64, 500, 8), (64, 128, 1024, 16),
                                     (17, 64, 300, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_logits(T, d, V, r, dtype):
    h = jax.random.normal(jax.random.PRNGKey(0), (T, d), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (d, V), dtype)
    a = jax.random.normal(jax.random.PRNGKey(2), (d, r), dtype)
    b = jax.random.normal(jax.random.PRNGKey(3), (r, V), dtype)
    out = lora_logits(h, w, a, b, 2.0, block_t=16, block_v=256, **I)
    expect = ref.ref_lora_logits(h, w, a, b, 2.0)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("B,H,KV,hd,S,bs", [
    (2, 8, 2, 32, 100, 32), (3, 16, 16, 64, 64, 64), (1, 4, 1, 128, 300, 128),
    (2, 8, 8, 64, 33, 16),
])
def test_decode_attention(B, H, KV, hd, S, bs):
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd))
    lens = jax.random.randint(jax.random.PRNGKey(3), (B,), 1, S + 1)
    out = decode_attention(q, k, v, lens, block_s=bs, **I)
    expect = ref.ref_decode_attention(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


@pytest.mark.parametrize("B,T,H,hd,ds,Q", [
    (2, 64, 4, 16, 32, 16), (1, 128, 8, 64, 128, 64), (2, 32, 2, 8, 16, 32),
])
def test_ssd_scan(B, T, H, hd, ds, Q):
    xh = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, hd))
    Bc = jax.random.normal(jax.random.PRNGKey(1), (B, T, 1, ds)) * 0.5
    Cc = jax.random.normal(jax.random.PRNGKey(2), (B, T, 1, ds)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(3), (B, T, H)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(4), (H,)) * 0.3)
    y, h = ssd_scan(xh, Bc, Cc, dt, A, chunk=Q, **I)
    y_ref, h_ref = ref.ref_ssd_scan(xh, Bc, Cc, dt, A, Q)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-4)


def test_ops_wrappers_jit():
    """ops.py jit'd wrappers dispatch to interpret mode on CPU."""
    from repro.kernels import ops
    h = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 256))
    arg, mx = ops.verify_argmax(h, w, block_t=8, block_v=128)
    arg_ref, _ = ref.ref_verify_argmax(h, w)
    np.testing.assert_array_equal(np.asarray(arg), np.asarray(arg_ref))
