"""Regenerate the data-driven sections of EXPERIMENTS.md from
experiments/dryrun/*.json (§Dry-run, §Roofline tables).  Hand-written
sections (§Setup, §Paper-claims, §Perf log) live in EXPERIMENTS.md between
markers and are preserved.

  PYTHONPATH=src python scripts/make_experiments_md.py
"""
from __future__ import annotations

import glob
import json
import os
import re
import sys

sys.path.insert(0, "src")

from repro.configs import INPUT_SHAPES  # noqa: E402
from repro.launch.dryrun import adapt_config  # noqa: E402
from repro.roofline import roofline_from_record, suggestion  # noqa: E402

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_b(x):
    for unit, div in (("TiB", 2**40), ("GiB", 2**30), ("MiB", 2**20)):
        if x >= div:
            return f"{x/div:.2f} {unit}"
    return f"{x:.0f} B"


def load(mesh):
    recs = {}
    for f in glob.glob(f"experiments/dryrun/*_{mesh}.json"):
        d = json.load(open(f))
        if "+" in d["arch"]:        # variant runs (e.g. +kvq) live in §Perf
            continue
        recs[(d["arch"], d["shape"])] = d
    return recs


def dryrun_table():
    single = load("16x16")
    multi = load("2x16x16")
    lines = ["| arch | shape | 16x16 | peak GiB/dev | dotFLOPs/dev | "
             "wire GiB/dev | 2x16x16 | peak GiB/dev | note |",
             "|---|---|---|---|---|---|---|---|---|"]
    n_ok = n_skip = n_fail = 0
    for shape in SHAPES:
        for (arch, sh), rec in sorted(single.items()):
            if sh != shape:
                continue
            m = multi.get((arch, sh), {"status": "—"})
            st = rec["status"]
            n_ok += st == "ok"
            n_skip += st == "skip"
            n_fail += st not in ("ok", "skip")
            if st == "ok":
                peak = f"{rec['memory']['peak_bytes']/2**30:.1f}"
                fl = f"{rec['cost']['dot_flops_per_device']:.3g}"
                wire = f"{rec['collectives']['total']['wire_bytes']/2**30:.2f}"
            else:
                peak = fl = wire = "—"
            mp_st = m.get("status", "—")
            mp_peak = (f"{m['memory']['peak_bytes']/2**30:.1f}"
                       if mp_st == "ok" else "—")
            note = rec.get("note") or rec.get("error", "")[:60] or ""
            lines.append(f"| {arch} | {shape} | {st} | {peak} | {fl} | "
                         f"{wire} | {mp_st} | {mp_peak} | {note} |")
    lines.append("")
    lines.append(f"**Totals (16x16):** {n_ok} ok / {n_skip} documented skips "
                 f"/ {n_fail} fail.")
    return "\n".join(lines)


def roofline_table():
    single = load("16x16")
    lines = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) |"
             " dominant | useful ratio | 6ND (global PF) | next move |",
             "|---|---|---|---|---|---|---|---|---|"]
    for shape in SHAPES:
        for (arch, sh), rec in sorted(single.items()):
            if sh != shape or rec["status"] != "ok":
                continue
            cfg, _ = adapt_config(arch, INPUT_SHAPES[sh])
            rl = roofline_from_record(rec, cfg, INPUT_SHAPES[sh])
            lines.append(
                f"| {arch} | {shape} | {rl['compute_s']*1e3:.3g} | "
                f"{rl['memory_s']*1e3:.3g} | {rl['collective_s']*1e3:.3g} | "
                f"**{rl['dominant']}** | {rl['useful_flops_ratio']:.2f} | "
                f"{rl['model_flops_6nd']/1e15:.3g} | {suggestion(rl)[:60]} |")
    return "\n".join(lines)


def main():
    path = "EXPERIMENTS.md"
    text = open(path).read() if os.path.exists(path) else ""
    dr = ("<!-- DRYRUN:BEGIN -->\n\n" + dryrun_table()
          + "\n\n<!-- DRYRUN:END -->")
    rf = ("<!-- ROOFLINE:BEGIN -->\n\n" + roofline_table()
          + "\n\n<!-- ROOFLINE:END -->")
    if "<!-- DRYRUN:BEGIN -->" in text:
        text = re.sub(r"<!-- DRYRUN:BEGIN -->.*?<!-- DRYRUN:END -->", dr,
                      text, flags=re.S)
        text = re.sub(r"<!-- ROOFLINE:BEGIN -->.*?<!-- ROOFLINE:END -->", rf,
                      text, flags=re.S)
    else:
        text += "\n## Dry-run\n" + dr + "\n\n## Roofline\n" + rf + "\n"
    open(path, "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
