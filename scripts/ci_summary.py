#!/usr/bin/env python
"""Append a one-row pass-count table for a pytest junitxml report to the
GitHub Actions job summary (``$GITHUB_STEP_SUMMARY``); prints to stdout
when run outside Actions.

  python scripts/ci_summary.py pytest-report.xml "tier1 py3.12 jax-latest"
"""
from __future__ import annotations

import os
import sys
import xml.etree.ElementTree as ET


def main():
    xml_path, label = sys.argv[1], sys.argv[2]
    try:
        root = ET.parse(xml_path).getroot()
    except (OSError, ET.ParseError) as e:
        row = f"| {label} | — | — | — | — | report missing ({e}) |"
    else:
        suite = root if root.tag == "testsuite" else root.find("testsuite")
        tests = int(suite.get("tests", 0))
        errors = int(suite.get("errors", 0))
        failures = int(suite.get("failures", 0))
        skipped = int(suite.get("skipped", 0))
        passed = tests - errors - failures - skipped
        t = float(suite.get("time", 0.0))
        row = (f"| {label} | {passed} | {failures + errors} | {skipped} "
               f"| {t:.0f}s | {'✅' if failures + errors == 0 else '❌'} |")
    header = ("| job | passed | failed | skipped | time | ok |\n"
              "|---|---:|---:|---:|---:|:--:|\n")
    out = os.environ.get("GITHUB_STEP_SUMMARY")
    if out:
        # write the header once per summary file, then one row per job step
        first = not (os.path.exists(out) and "| job | passed |"
                     in open(out).read())
        with open(out, "a") as f:
            f.write((header if first else "") + row + "\n")
    print(header + row)


if __name__ == "__main__":
    main()
