#!/usr/bin/env python
"""Bench-regression gate for CI: compare a fresh serving_bench --json record
against the committed baseline and FAIL (exit 1) when

* the ``continuous-fused`` arm's ``blocks_per_s`` regressed more than
  ``--tolerance`` (default 20%) vs ``benchmarks/baseline.json``,
* the WITHIN-RUN fusion speedup ratio (``fused_speedup_blocks_per_s`` —
  fused vs per-block arm on the same machine in the same run, so immune
  to runner hardware variance) regressed more than ``--tolerance``,
* the fused arm's ``mean_accepted_tokens`` (committed tokens per verify
  pass — the speculative-decoding quality number, hardware-independent)
  regressed more than ``--tolerance`` vs baseline (schema v3+), or
* any stream-identity check in the run came back false (``streams_match``
  for the fused arm, the mixed chunked-prefill arm, and the prefix-cached
  arm when present) — losslessness is a correctness property, not a perf
  number, or
* a v5 ``prefix_cache`` block is present but the cache bought neither
  >=1.5x admitted/s nor >=50% of prefill work skipped.

Also prints a trajectory delta table, appended to ``$GITHUB_STEP_SUMMARY``
when set so the bench trajectory is readable from the PR checks page.

Usage (exactly what CI runs):

  PYTHONPATH=src python benchmarks/serving_bench.py --smoke --paged \
      --json bench-smoke.json
  python scripts/check_bench_regression.py bench-smoke.json \
      --baseline benchmarks/baseline.json

Refreshing the baseline: download ``bench-smoke.json`` from a recent green
run's ``bench-trajectory`` artifact (CI uploads it every run) and commit it
over ``benchmarks/baseline.json`` — a CI-produced baseline keeps the
absolute ``blocks_per_s`` comparison on CI-runner hardware, where it is
meaningful.  A locally produced baseline also works (the within-run ratio
check is hardware-independent either way) but makes the absolute check
noisier — in particular, the FIRST CI run after seeding the baseline from
a dev machine may trip the absolute check on hardware delta alone; refresh
from that run's artifact and it stabilizes.  Keep the ``git_sha``/``schema_version`` stamp — it records where
the numbers came from; only baselines with the same ``schema_version`` are
accepted.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def fused_arm(rec: dict) -> dict:
    """The continuous-fused arm is the serving hot path the gate guards."""
    if rec.get("mode") == "drift":
        raise SystemExit(
            "this is a drift-trace record (serving_bench --drift); the "
            "drift suite self-asserts its gates — the regression checker "
            "only takes scheduler-arm records")
    arms = [a for a in rec.get("arms", [])
            if a["scheduler"].startswith("continuous-fused")]
    if not arms:
        raise SystemExit("no continuous-fused arm in the bench record")
    return arms[0]


def collect_rows(cur: dict, base: dict):
    """(metric, baseline, current, delta%) rows for the summary table."""
    fc, fb = fused_arm(cur), fused_arm(base)

    def pct(new, old):
        return 100.0 * (new - old) / old if old else float("nan")

    rows = [("fused blocks_per_s", fb["blocks_per_s"], fc["blocks_per_s"],
             pct(fc["blocks_per_s"], fb["blocks_per_s"]))]
    for key, label in (("tok_per_s", "fused tok_per_s"),
                       ("p95_ms", "fused p95_ms"),
                       ("acceptance", "fused acceptance"),
                       ("mean_accepted_tokens", "fused MAT")):
        if key in fc and key in fb:
            rows.append((label, fb[key], fc[key], pct(fc[key], fb[key])))
    sc = cur.get("fused", {}).get("fused_speedup_blocks_per_s")
    sb = base.get("fused", {}).get("fused_speedup_blocks_per_s")
    if sc and sb:
        rows.append(("within-run fusion speedup (x)", sb, sc, pct(sc, sb)))
    pc = cur.get("fused", {}).get("prefill") or {}
    pb = base.get("fused", {}).get("prefill") or {}
    if pc.get("tick_p95_ms_chunked") and pb.get("tick_p95_ms_chunked"):
        rows.append(("mixed tick_p95_ms (chunked)",
                     pb["tick_p95_ms_chunked"], pc["tick_p95_ms_chunked"],
                     pct(pc["tick_p95_ms_chunked"],
                         pb["tick_p95_ms_chunked"])))
    xc = cur.get("fused", {}).get("prefix_cache") or {}
    xb = base.get("fused", {}).get("prefix_cache") or {}
    for key, label in (("saved_frac", "prefix prefill saved (frac)"),
                       ("admit_speedup", "prefix admit speedup (x)")):
        if xc.get(key) and xb.get(key):
            rows.append((label, xb[key], xc[key], pct(xc[key], xb[key])))
    return rows


def render(rows, cur, base, failures) -> str:
    out = ["### Serving bench trajectory",
           f"current `{cur.get('git_sha', '?')}` vs baseline "
           f"`{base.get('git_sha', '?')}` "
           f"(schema v{cur.get('schema_version', '?')})", "",
           "| metric | baseline | current | delta |",
           "|---|---:|---:|---:|"]
    for label, b, c, d in rows:
        out.append(f"| {label} | {b:.3f} | {c:.3f} | {d:+.1f}% |")
    out.append("")
    out.append("**FAIL**: " + "; ".join(failures) if failures
               else "**PASS**: no regression beyond tolerance, streams match")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="bench --json output to check")
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="max allowed fractional blocks_per_s regression")
    args = ap.parse_args()

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    failures = []
    # v4/v5 only ADD keys over v3 (v4: per-arm `metrics` snapshot, drift
    # train_timeline; v5: prefix-cache arms + `prefix_cache` summary), so
    # older baselines stay comparable with a newer current — every key this
    # script reads exists in both, and the v5 prefix gates below only fire
    # when the current run carries the block
    compatible = {3, 4, 5}
    sv_cur, sv_base = cur.get("schema_version"), base.get("schema_version")
    if sv_cur not in compatible or sv_base not in compatible:
        raise SystemExit(
            f"baseline schema v{sv_base} vs current v{sv_cur}: this script "
            f"compares schema versions {sorted(compatible)} only — refresh "
            "benchmarks/baseline.json (see this script's docstring)")

    if not cur.get("fused", {}).get("streams_match", False):
        failures.append("fused arm token streams diverged from per-block "
                        "scheduling (streams_match=false)")
    prefill = cur.get("fused", {}).get("prefill")
    if prefill is not None and not prefill.get("streams_match", False):
        failures.append("chunked-prefill arm token streams diverged from "
                        "one-shot prefill (streams_match=false)")
    # v5 prefix-cache gates: identity is non-negotiable, and the cache must
    # buy a real saving (admission speed or prefill work) — the same bar
    # serving_bench hard-asserts, re-checked here so a stale artifact can't
    # sneak past a locally patched bench
    pfx = cur.get("fused", {}).get("prefix_cache")
    if pfx is not None:
        if not pfx.get("streams_match", False):
            failures.append("prefix-cached arm token streams diverged from "
                            "cold prefill (streams_match=false)")
        if not (pfx.get("admit_speedup", 0) >= 1.5
                or pfx.get("saved_frac", 0) >= 0.5):
            failures.append(
                f"prefix cache bought neither admission speed "
                f"(x{pfx.get('admit_speedup', 0):.2f} < 1.5) nor prefill "
                f"work ({pfx.get('saved_frac', 0):.0%} < 50%)")

    fc, fb = fused_arm(cur), fused_arm(base)
    regress = (fb["blocks_per_s"] - fc["blocks_per_s"]) / fb["blocks_per_s"]
    if regress > args.tolerance:
        failures.append(
            f"fused blocks_per_s regressed {regress:.1%} "
            f"({fb['blocks_per_s']:.1f} -> {fc['blocks_per_s']:.1f}), "
            f"tolerance {args.tolerance:.0%}")

    # speculative-decoding QUALITY gate: committed tokens per verify pass on
    # the fused arm.  Hardware-independent (a token count, not a timing), so
    # it catches drafter/acceptance regressions that blocks_per_s hides —
    # e.g. a bug that silently rejects good drafts but makes blocks cheaper
    mc, mb = fc.get("mean_accepted_tokens"), fb.get("mean_accepted_tokens")
    if mc is not None and mb:
        mat_regress = (mb - mc) / mb
        if mat_regress > args.tolerance:
            failures.append(
                f"fused mean_accepted_tokens regressed {mat_regress:.1%} "
                f"({mb:.2f} -> {mc:.2f}), tolerance {args.tolerance:.0%}")

    # hardware-independent backstop: the fused-vs-per-block speedup is a
    # ratio of two arms measured in the SAME run on the SAME machine
    sc = cur.get("fused", {}).get("fused_speedup_blocks_per_s")
    sb = base.get("fused", {}).get("fused_speedup_blocks_per_s")
    if sc and sb:
        ratio_regress = (sb - sc) / sb
        if ratio_regress > args.tolerance:
            failures.append(
                f"within-run fusion speedup regressed {ratio_regress:.1%} "
                f"({sb:.2f}x -> {sc:.2f}x), tolerance {args.tolerance:.0%}")

    report = render(collect_rows(cur, base), cur, base, failures)
    print(report)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(report + "\n")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
