#!/usr/bin/env python
"""Metrics-artifact schema gate for CI: validate a telemetry snapshot and
FAIL (exit 1) when the ``dvi_serving_*`` / ``dvi_train_*`` contract (the
normative reference is ``src/repro/serving/telemetry.py``'s docstring) is
broken:

* a required metric is missing, or a metric's declared type is wrong,
* a counter or histogram carries a negative value,
* a histogram's cumulative bucket counts are not non-decreasing, its +Inf
  cumulative count != its ``count``, or ``count``/``sum`` are inconsistent
  with the buckets,
* the in-graph per-block histograms do not reconcile EXACTLY with the flat
  counters they shadow:
    - ``dvi_serving_block_accepted_drafts``: count == blocks_total,
      sum == accepted_drafts_total
    - ``dvi_serving_block_depth``: count == blocks_total,
      sum == drafted_tokens_total
  (integer identities — the histograms are computed inside the fused
  superstep and folded from the SAME device_get as the counters, so any
  drift means the zero-host-sync accounting is wrong, not "sampling
  noise"),
* the prefix-cache counters do not reconcile EXACTLY:
    - ``prefix_hits_total + prefix_misses_total == prefix_lookups_total``
      (every lookup is classified exactly once),
    - ``prefix_hit_tokens_total >= prefix_hits_total`` (a hit splices at
      least one token),
    - ``prefix_cow_copies_total <= prefix_hits_total`` (copy-on-write
      only ever rides a hit),
* the request-lifecycle counters do not reconcile EXACTLY (artifacts are
  written AFTER the engine drains, so no request may be unaccounted):
    - ``submitted_total == requests_total + cancelled_total +
      rejected_total + queue_depth + live_slots`` (every submission ends
      completed, cancelled, or rejected once the engine is idle),
    - the per-tenant label values of ``requests_by_tenant`` sum to
      ``submitted_total`` (every submission is attributed to exactly one
      tenant, including rejected ones).

Accepted inputs:

* a snapshot JSON written by ``--metrics-out foo.json``,
* a Prometheus text file written by ``--metrics-out foo.prom`` (any
  non-.json suffix),
* a full ``serving_bench.py --json`` artifact (schema v4: every arm's
  ``metrics`` snapshot is validated; drift artifacts validate each drift
  arm's snapshot).

Usage (what CI runs on the bench-smoke artifacts):

  python scripts/check_metrics_schema.py metrics-smoke.json
  python scripts/check_metrics_schema.py bench-smoke.json
"""
from __future__ import annotations

import argparse
import json
import sys

sys.path.insert(0, "src")

from repro.serving.telemetry import parse_prometheus_text  # noqa: E402

# (name, type) pairs every engine snapshot must expose, regardless of
# scheduler / paging / learning configuration — the registry declares the
# full schema up front so dashboards never see keys flicker in and out
REQUIRED = {
    "dvi_serving_requests_total": "counter",
    "dvi_serving_submitted_total": "counter",
    "dvi_serving_cancelled_total": "counter",
    "dvi_serving_rejected_total": "counter",
    "dvi_serving_requests_by_tenant": "counter",
    "dvi_serving_blocks_total": "counter",
    "dvi_serving_steps_total": "counter",
    "dvi_serving_committed_tokens_total": "counter",
    "dvi_serving_accepted_drafts_total": "counter",
    "dvi_serving_drafted_tokens_total": "counter",
    "dvi_serving_preemptions_total": "counter",
    "dvi_serving_host_syncs_total": "counter",
    "dvi_serving_sync_wait_seconds_total": "counter",
    "dvi_serving_dispatches_total": "counter",
    "dvi_serving_prefill_chunks_total": "counter",
    "dvi_serving_prefill_tokens_total": "counter",
    "dvi_serving_kv_watermark_hits_total": "counter",
    "dvi_serving_prefix_lookups_total": "counter",
    "dvi_serving_prefix_hits_total": "counter",
    "dvi_serving_prefix_misses_total": "counter",
    "dvi_serving_prefix_hit_tokens_total": "counter",
    "dvi_serving_prefix_cow_copies_total": "counter",
    "dvi_serving_prefix_evictions_total": "counter",
    "dvi_serving_peak_live_slots": "gauge",
    "dvi_serving_live_slots": "gauge",
    "dvi_serving_queue_depth": "gauge",
    "dvi_serving_max_tick_prefill_tokens": "gauge",
    "dvi_serving_kv_used_pages": "gauge",
    "dvi_serving_kv_free_pages": "gauge",
    "dvi_serving_kv_cached_pages": "gauge",
    "dvi_serving_depth_mean": "gauge",
    "dvi_serving_request_latency_seconds": "histogram",
    "dvi_serving_queue_wait_seconds": "histogram",
    "dvi_serving_ttft_seconds": "histogram",
    "dvi_serving_tick_seconds": "histogram",
    "dvi_serving_sync_wait_seconds": "histogram",
    "dvi_serving_block_accepted_drafts": "histogram",
    "dvi_serving_block_depth": "histogram",
    "dvi_train_updates_total": "counter",
    "dvi_train_step": "gauge",
    "dvi_train_phase": "gauge",
    "dvi_train_lambda_pg": "gauge",
    "dvi_train_lambda_kl": "gauge",
    "dvi_train_beta": "gauge",
    "dvi_train_loss": "gauge",
    "dvi_train_loss_kl": "gauge",
    "dvi_train_loss_ce": "gauge",
    "dvi_train_loss_pg": "gauge",
    "dvi_train_acceptance_batch": "gauge",
    "dvi_train_acceptance_ema_before": "gauge",
    "dvi_train_acceptance_ema_after": "gauge",
    "dvi_train_buffer_count": "gauge",
    "dvi_train_gnorm": "gauge",
    "dvi_train_update_span_seconds": "histogram",
}

# histogram -> (count must equal, sum must equal): the exact-integer
# reconciliation identities between the in-graph per-block histograms and
# the flat counters harvested from the same device_get
RECONCILE = {
    "dvi_serving_block_accepted_drafts": (
        "dvi_serving_blocks_total", "dvi_serving_accepted_drafts_total"),
    "dvi_serving_block_depth": (
        "dvi_serving_blocks_total", "dvi_serving_drafted_tokens_total"),
}


def check_snapshot(snap: dict, label: str) -> list:
    errs = []

    def err(msg):
        errs.append(f"[{label}] {msg}")

    for name, kind in REQUIRED.items():
        m = snap.get(name)
        if m is None:
            err(f"missing required metric {name}")
            continue
        if m.get("type") != kind:
            err(f"{name}: type {m.get('type')!r} != declared {kind!r}")

    for name, m in snap.items():
        kind = m.get("type")
        if kind == "counter":
            if m.get("value", 0) < 0:
                err(f"{name}: negative counter value {m['value']}")
            vals = m.get("values")
            if vals is not None:
                if any(v < 0 for v in vals.values()):
                    err(f"{name}: negative labeled counter value {vals}")
                if sum(vals.values()) != m.get("value", 0):
                    err(f"{name}: label values sum {sum(vals.values())} "
                        f"!= total {m.get('value', 0)}")
        elif kind == "histogram":
            buckets = m.get("buckets", [])
            if not buckets:
                err(f"{name}: histogram has no buckets")
                continue
            cums = [c for _, c in buckets]
            if any(c < 0 for c in cums) or m.get("count", 0) < 0:
                err(f"{name}: negative bucket/count")
            if any(a > b for a, b in zip(cums, cums[1:])):
                err(f"{name}: cumulative bucket counts decrease: {cums}")
            if buckets[-1][0] != "+Inf":
                err(f"{name}: last bucket bound is {buckets[-1][0]}, "
                    f"not +Inf")
            elif cums[-1] != m.get("count"):
                err(f"{name}: +Inf cumulative {cums[-1]} != count "
                    f"{m.get('count')}")

    # the per-block histograms are folded from the continuous superstep
    # harvest; the legacy sync scheduler never dispatches supersteps, so
    # there they must simply stay empty (dispatches_total == 0)
    superstep_ran = snap.get("dvi_serving_dispatches_total",
                             {}).get("value", 0) > 0
    for hname, (count_of, sum_of) in RECONCILE.items():
        h = snap.get(hname)
        if h is None or count_of not in snap or sum_of not in snap:
            continue                         # missing keys reported above
        if not superstep_ran:
            if h["count"] != 0:
                err(f"{hname}: nonzero count {h['count']} with no "
                    f"superstep dispatches")
            continue
        if h["count"] != snap[count_of]["value"]:
            err(f"{hname}: count {h['count']} != "
                f"{count_of} {snap[count_of]['value']}")
        if h["sum"] != snap[sum_of]["value"]:
            err(f"{hname}: sum {h['sum']} != "
                f"{sum_of} {snap[sum_of]['value']}")

    # prefix-cache counter identities (exact — every acquire_prefix call
    # increments lookups and EXACTLY ONE of hits/misses): hits + misses ==
    # lookups; a hit splices at least one token (hit_tokens >= hits); a COW
    # copy only ever rides a hit (cow_copies <= hits)
    def cval(name):
        m = snap.get(name)
        return None if m is None else m.get("value", 0)

    lookups = cval("dvi_serving_prefix_lookups_total")
    hits = cval("dvi_serving_prefix_hits_total")
    misses = cval("dvi_serving_prefix_misses_total")
    hit_toks = cval("dvi_serving_prefix_hit_tokens_total")
    cows = cval("dvi_serving_prefix_cow_copies_total")
    if None not in (lookups, hits, misses):
        if hits + misses != lookups:
            err(f"prefix counters do not reconcile: hits {hits} + misses "
                f"{misses} != lookups {lookups}")
        if hit_toks is not None and hit_toks < hits:
            err(f"prefix_hit_tokens {hit_toks} < prefix_hits {hits} "
                f"(every hit splices >= 1 token)")
        if cows is not None and cows > hits:
            err(f"prefix_cow_copies {cows} > prefix_hits {hits} "
                f"(COW only rides a hit)")

    # request-lifecycle reconciliation: artifacts are written after the
    # engine drains, so every submission must be accounted for — completed
    # (requests_total), cancelled, rejected, or still parked in the queue /
    # a live lane (both zero when drained; kept in the identity so the
    # check is also meaningful on mid-run snapshots)
    submitted = cval("dvi_serving_submitted_total")
    completed = cval("dvi_serving_requests_total")
    cancelled = cval("dvi_serving_cancelled_total")
    rejected = cval("dvi_serving_rejected_total")
    qdepth = (snap.get("dvi_serving_queue_depth") or {}).get("value")
    live = (snap.get("dvi_serving_live_slots") or {}).get("value")
    if None not in (submitted, completed, cancelled, rejected, qdepth, live):
        accounted = completed + cancelled + rejected + qdepth + live
        if submitted != accounted:
            err(f"lifecycle counters do not reconcile: submitted "
                f"{submitted} != completed {completed} + cancelled "
                f"{cancelled} + rejected {rejected} + queue_depth "
                f"{qdepth} + live_slots {live} = {accounted}")
        tenants = (snap.get("dvi_serving_requests_by_tenant") or
                   {}).get("values")
        if tenants is not None and sum(tenants.values()) != submitted:
            err(f"requests_by_tenant values {tenants} sum to "
                f"{sum(tenants.values())} != submitted_total {submitted}")
    return errs


def extract_snapshots(path: str) -> dict:
    """{label: snapshot} from a snapshot JSON / Prometheus text / bench
    artifact."""
    if not path.endswith(".json"):
        with open(path) as f:
            return {path: parse_prometheus_text(f.read())}
    with open(path) as f:
        doc = json.load(f)
    if "arms" in doc and isinstance(doc["arms"], list):      # bench artifact
        return {a["scheduler"]: a["metrics"] for a in doc["arms"]
                if "metrics" in a}
    if "drift" in doc:                                       # drift artifact
        return {f"drift:{k}": v["metrics"]
                for k, v in doc["drift"]["arms"].items() if "metrics" in v}
    return {path: doc}                                       # bare snapshot


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("artifact", help="metrics snapshot (.json / Prometheus "
                                     "text) or serving_bench --json output")
    args = ap.parse_args()

    snaps = extract_snapshots(args.artifact)
    if not snaps:
        raise SystemExit(f"{args.artifact}: no metrics snapshots found "
                         f"(pre-v4 bench artifact?)")
    errs = []
    for label, snap in snaps.items():
        errs.extend(check_snapshot(snap, label))
    for e in errs:
        print(f"FAIL: {e}")
    if errs:
        raise SystemExit(1)
    print(f"OK: {len(snaps)} snapshot(s) in {args.artifact} conform to the "
          f"dvi_serving_*/dvi_train_* schema "
          f"({len(REQUIRED)} required metrics, per-block histograms "
          f"reconcile exactly)")


if __name__ == "__main__":
    main()
