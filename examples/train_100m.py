"""End-to-end driver: pretrain a ~100M-parameter decoder for a few hundred
steps, then run the paper's DVI protocol on it (online drafter learning
with a KL->RL schedule) and report the resulting lossless speedup.

Default scale is CPU-feasible (~10 min); pass --full for the 100M config.

    PYTHONPATH=src python examples/train_100m.py [--full] [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint, save_lora
from repro.configs import get_config
from repro.configs.base import DVIConfig
from repro.core import online, spec
from repro.data import SyntheticTasks, TASK_CATEGORIES
from repro.models.model import build_model
from repro.training import pretrain


def config(full: bool):
    base = get_config("vicuna-7b", tiny=True)
    if not full:
        return base.replace(dtype="float32")
    # ~100M params: 12L x d640 x ff2560, 16k vocab
    return base.replace(
        name="dvi-100m", num_layers=12, d_model=640, num_heads=10,
        num_kv_heads=10, head_dim=64, d_ff=2560, vocab_size=16_384,
        dtype="float32",
        dvi=DVIConfig(split_layer=2, k_spec=4, lora_rank=32,
                      buffer_slots=2048, batch_size=128))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dvi-prompts", type=int, default=400)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = config(args.full)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"backbone: {cfg.name}, {n_params/1e6:.1f}M params")

    tasks = SyntheticTasks(cfg.vocab_size, seed=0)
    t0 = time.time()
    params, losses = pretrain(
        model, params,
        tasks.stream(TASK_CATEGORIES, args.steps, 8, 64, seed=9),
        lr=1.5e-3, log_every=args.steps // 5)
    print(f"pretrain: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({time.time()-t0:.0f}s, {args.steps} steps)")
    if args.ckpt:
        save_checkpoint(args.ckpt + ".backbone.npz", params)

    state = online.init_trainer(model, jax.random.PRNGKey(7))
    n_batches = args.dvi_prompts // 8
    stream = tasks.stream(TASK_CATEGORIES, n_batches, 8, 16, seed=1)
    t0 = time.time()
    state, hist = online.online_loop(model, params, stream, state,
                                     max_new=24, lr=3e-3,
                                     log_every=max(n_batches // 5, 1))
    print(f"DVI online: acceptance "
          f"{np.mean(hist['block_acc'][:5]):.2f} -> "
          f"{np.mean(hist['block_acc'][-5:]):.2f} ({time.time()-t0:.0f}s, "
          f"{int(state.step)} updates over {args.dvi_prompts} prompts)")
    if args.ckpt:
        save_lora(args.ckpt + ".lora.npz", state.dvi_params, int(state.step),
                  float(state.baseline))

    # final eval: lossless speedup on held-out prompts
    prompts = jnp.asarray(tasks.sample("math", 8, 16, seed=777))
    ar = jax.jit(lambda p: spec.ar_generate(model, params, p, 48))
    dv = jax.jit(lambda p: spec.speculative_generate(
        model, params, state.dvi_params, p, 48))
    jax.block_until_ready(ar(prompts).tokens)
    jax.block_until_ready(dv(prompts).tokens)
    t0 = time.perf_counter(); r_ar = ar(prompts)
    jax.block_until_ready(r_ar.tokens); t_ar = time.perf_counter() - t0
    t0 = time.perf_counter(); r_dv = dv(prompts)
    jax.block_until_ready(r_dv.tokens); t_dv = time.perf_counter() - t0
    ok = all(bool(jnp.all(
        r_ar.tokens[b, :min(int(r_ar.lengths[b]), int(r_dv.lengths[b]))] ==
        r_dv.tokens[b, :min(int(r_ar.lengths[b]), int(r_dv.lengths[b]))]))
        for b in range(8))
    print(f"eval: lossless={ok}  speedup={t_ar/t_dv:.2f}x  "
          f"MAT={float(r_dv.committed)/float(r_dv.blocks):.2f}")


if __name__ == "__main__":
    main()
