"""DVI across architecture families: the same Draft->Verify->Improve loop
runs unmodified on a dense GQA model, an attention-free SSM (Mamba-2, with
per-step state rollback), and a top-k MoE (with dropless decode dispatch) —
all losslessly.

    PYTHONPATH=src python examples/multi_arch.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import online, spec
from repro.data import SyntheticTasks, TASK_CATEGORIES
from repro.models.model import build_model
from repro.training import pretrain

ARCHS = ["qwen3-0.6b", "mamba2-370m", "llama4-scout-17b-a16e"]


def main():
    for name in ARCHS:
        cfg = get_config(name, tiny=True).replace(dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tasks = SyntheticTasks(cfg.vocab_size, seed=0)
        params, _ = pretrain(model, params,
                             tasks.stream(TASK_CATEGORIES, 150, 16, 32, seed=9),
                             lr=2e-3)
        state = online.init_trainer(model, jax.random.PRNGKey(7))
        state, hist = online.online_loop(
            model, params, tasks.stream(TASK_CATEGORIES, 40, 8, 16, seed=1),
            state, max_new=24, lr=3e-3)

        prompts = jnp.asarray(tasks.sample("rag", 4, 12, seed=5))
        r_ar = spec.ar_generate(model, params, prompts, 32)
        r_dv = spec.speculative_generate(model, params, state.dvi_params,
                                         prompts, 32)
        ok = all(bool(jnp.all(
            r_ar.tokens[b, :min(int(r_ar.lengths[b]), int(r_dv.lengths[b]))] ==
            r_dv.tokens[b, :min(int(r_ar.lengths[b]), int(r_dv.lengths[b]))]))
            for b in range(4))
        print(f"{name:26s} [{cfg.arch_type:6s}] lossless={ok} "
              f"MAT={float(r_dv.committed)/float(r_dv.blocks):.2f} "
              f"final_acc={np.mean(hist['block_acc'][-8:]):.2f}")


if __name__ == "__main__":
    main()
