"""Quickstart: Draft, Verify, & Improve in ~60 lines.

Builds a tiny Vicuna-family backbone, pretrains it briefly on a synthetic
task mixture (so the verifier is peaked, like a real LM), then:

 1. decodes greedily (AR baseline),
 2. decodes with DVI self-speculation (losslessly — same tokens),
 3. runs the online KL->RL loop and shows acceptance/MAT climbing.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import lora, online, spec
from repro.data import SyntheticTasks, TASK_CATEGORIES
from repro.models.model import build_model
from repro.training import pretrain


def main():
    cfg = get_config("vicuna-7b", tiny=True).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tasks = SyntheticTasks(cfg.vocab_size, seed=0)

    print("== pretraining the backbone (substrate) ==")
    params, losses = pretrain(model, params,
                              tasks.stream(TASK_CATEGORIES, 200, 16, 32, seed=9),
                              lr=2e-3, log_every=100)

    prompts = jnp.asarray(tasks.sample("qa", 4, 12, seed=5))

    print("\n== 1) greedy AR decoding (the target distribution) ==")
    t0 = time.perf_counter()
    r_ar = spec.ar_generate(model, params, prompts, 48)
    t_ar = time.perf_counter() - t0
    print(f"   {int(r_ar.committed)} tokens in {t_ar:.2f}s")

    print("\n== 2) DVI self-speculation (drafter untrained -> static self-spec) ==")
    dvi_params = lora.init_draft_params(jax.random.PRNGKey(5), cfg)
    r_sd = spec.speculative_generate(model, params, dvi_params, prompts, 48)
    same = all(bool(jnp.all(
        r_ar.tokens[b, :min(int(r_ar.lengths[b]), int(r_sd.lengths[b]))] ==
        r_sd.tokens[b, :min(int(r_ar.lengths[b]), int(r_sd.lengths[b]))]))
        for b in range(4))
    print(f"   lossless vs AR: {same}   "
          f"MAT={float(r_sd.committed)/float(r_sd.blocks):.2f}")

    print("\n== 3) Improve: online KL->RL drafter training ==")
    state = online.init_trainer(model, jax.random.PRNGKey(7))
    stream = tasks.stream(TASK_CATEGORIES, 60, 8, 16, seed=1)
    state, hist = online.online_loop(model, params, stream, state,
                                     max_new=24, lr=3e-3, log_every=20)
    print(f"   block acceptance {np.mean(hist['block_acc'][:8]):.2f} -> "
          f"{np.mean(hist['block_acc'][-8:]):.2f}; "
          f"MAT {np.mean(hist['mat'][:8]):.2f} -> "
          f"{np.mean(hist['mat'][-8:]):.2f}")

    print("\n== 4) trained drafter: wall-time speedup (still lossless) ==")
    gen = jax.jit(lambda pr: spec.speculative_generate(
        model, params, state.dvi_params, pr, 48))
    gen(prompts)          # compile
    t0 = time.perf_counter()
    r_tr = gen(prompts)
    jax.block_until_ready(r_tr.tokens)
    t_sd = time.perf_counter() - t0
    ar = jax.jit(lambda pr: spec.ar_generate(model, params, pr, 48))
    ar(prompts)
    t0 = time.perf_counter()
    jax.block_until_ready(ar(prompts).tokens)
    t_ar = time.perf_counter() - t0
    print(f"   AR {t_ar:.2f}s vs DVI {t_sd:.2f}s -> {t_ar/t_sd:.2f}x speedup, "
          f"MAT={float(r_tr.committed)/float(r_tr.blocks):.2f}")


if __name__ == "__main__":
    main()
