"""Continual-learning serving under distribution drift.

The paper's core argument for training-aware speculation: offline-trained
drafters go stale when traffic drifts.  This demo serves QA-style traffic,
then switches to math-style mid-run:

* a FROZEN drafter's acceptance drops at the shift and stays low;
* the ONLINE (DVI) drafter's acceptance drops and then recovers.

    PYTHONPATH=src python examples/serve_drift.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import online
from repro.data import SyntheticTasks
from repro.models.model import build_model
from repro.serving import Request, ServingEngine
from repro.training import pretrain

PHASE1, PHASE2 = "qa", "math"
N_BATCHES = 30
SHIFT_AT = 10
BATCH = 8


def run(learn: bool, model, params, tasks, warm_state):
    state = online.OnlineTrainerState(
        dvi_params=jax.tree.map(lambda a: a, warm_state.dvi_params),
        opt_state=jax.tree.map(lambda a: a, warm_state.opt_state),
        buf=jax.tree.map(lambda a: a, warm_state.buf),
        baseline=warm_state.baseline, step=warm_state.step)
    eng = ServingEngine(model, params, state, batch_size=BATCH, max_new=24,
                        buckets=(16,), learn=learn, updates_per_batch=2)
    curve = []
    uid = 0
    for b in range(N_BATCHES):
        cat = PHASE1 if b < SHIFT_AT else PHASE2
        for _ in range(BATCH):
            eng.submit(Request(uid=uid,
                               prompt=tasks.sample(cat, 1, 16, seed=uid)[0]))
            uid += 1
        before = (eng.stats["accepted"], eng.stats["drafted"])
        eng.step()
        da = eng.stats["accepted"] - before[0]
        dd = eng.stats["drafted"] - before[1]
        curve.append(da / max(dd, 1))
    return curve


def main():
    cfg = get_config("vicuna-7b", tiny=True).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tasks = SyntheticTasks(cfg.vocab_size, seed=0)
    params, _ = pretrain(model, params, tasks.stream((PHASE1,), 200, 16, 32,
                                                     seed=9), lr=2e-3)

    # warm the drafter on phase-1 traffic only
    warm = online.init_trainer(model, jax.random.PRNGKey(7))
    warm, _ = online.online_loop(model, params,
                                 tasks.stream((PHASE1,), 40, 8, 16, seed=1),
                                 warm, max_new=24, lr=3e-3)

    frozen = run(False, model, params, tasks, warm)
    adaptive = run(True, model, params, tasks, warm)

    print(f"\nacceptance per batch (shift at batch {SHIFT_AT}):")
    print("batch:   " + " ".join(f"{i:5d}" for i in range(0, N_BATCHES, 3)))
    print("frozen:  " + " ".join(f"{frozen[i]:5.2f}" for i in range(0, N_BATCHES, 3)))
    print("online:  " + " ".join(f"{adaptive[i]:5.2f}" for i in range(0, N_BATCHES, 3)))
    f_post = np.mean(frozen[SHIFT_AT + 5:])
    a_post = np.mean(adaptive[SHIFT_AT + 5:])
    print(f"\npost-shift acceptance: frozen={f_post:.3f} online={a_post:.3f} "
          f"(recovery +{a_post - f_post:.3f})")


if __name__ == "__main__":
    main()
