"""Continual-learning serving under distribution drift.

The paper's core argument for training-aware speculation: offline-trained
drafters go stale when traffic drifts.  This demo serves QA-style traffic
through the continuous superstep engine, then switches to math-style
mid-run:

* a FROZEN drafter's acceptance drops at the shift and stays low — and its
  per-lane adaptive depth K throttles to the floor and stays there;
* the ONLINE (DVI) drafter's acceptance drops and then recovers — and the
  depth controller tracks the recovery, drafting deep again once the
  verifier starts accepting.

The acceptance curve shows the drafter's health; the adaptive-K trajectory
shows the speculative machinery reacting to it in real time.

    PYTHONPATH=src python examples/serve_drift.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import online
from repro.core.schedule import DepthConfig
from repro.data import SyntheticTasks, TASK_CATEGORIES
from repro.models.model import build_model
from repro.serving import Request, ServingEngine
from repro.training import pretrain

PHASE1, PHASE2 = "qa", "math"
N_BATCHES = 30
SHIFT_AT = 10
BATCH = 8
MAX_NEW = 24
PROMPT_LEN = 16
# Pin the controller's target band between the healthy phase-1 acceptance
# (~0.8) and the degraded post-shift level (the un-tuned drafter shares the
# verifier's trunk, so agreement degrades rather than collapses): depth
# should throttle exactly when the drafter goes stale.
DEPTH = DepthConfig(k_min=1, k_max=4, k_init=4, ema_alpha=0.3,
                    hi=0.80, lo=0.60, cooldown=3, ema_init=0.75)


def run(learn: bool, model, params, tasks, warm_state):
    state = online.OnlineTrainerState(
        dvi_params=jax.tree.map(lambda a: a, warm_state.dvi_params),
        opt_state=jax.tree.map(lambda a: a, warm_state.opt_state),
        buf=jax.tree.map(lambda a: a, warm_state.buf),
        baseline=warm_state.baseline, step=warm_state.step)
    eng = ServingEngine(model, params, state, scheduler="continuous",
                        num_slots=BATCH, batch_size=BATCH, max_new=MAX_NEW,
                        buckets=(PROMPT_LEN,), learn=learn,
                        updates_per_batch=2, sync_every=2,
                        adaptive_k=True, depth_cfg=DEPTH)
    acc, depth = [], []
    uid = 0
    for b in range(N_BATCHES):
        cat = PHASE1 if b < SHIFT_AT else PHASE2
        for _ in range(BATCH):
            eng.submit_request(Request(uid=uid,
                                       prompt=tasks.sample(cat, 1, PROMPT_LEN,
                                                           seed=uid)[0],
                                       max_new=MAX_NEW))
            uid += 1
        before = (eng.stats["accepted"], eng.stats["drafted"],
                  eng.stats["blocks"])
        while eng.busy:                 # closed loop: drain the batch
            eng.step()
        da = eng.stats["accepted"] - before[0]
        dd = eng.stats["drafted"] - before[1]
        db = eng.stats["blocks"] - before[2]
        acc.append(da / max(dd, 1))
        depth.append(dd / max(db, 1))   # drafted per block = realized K
    return acc, depth, eng


def main():
    cfg = get_config("vicuna-7b", tiny=True).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tasks = SyntheticTasks(cfg.vocab_size, seed=0)
    # the VERIFIER is a general model (all six categories, briefly); only
    # the DRAFTER's LoRA is tuned to recent traffic.  That asymmetry is what
    # makes the drafter go stale: on the unseen category its acceptance
    # rides on a LoRA trained for somewhere else.
    params, _ = pretrain(model, params,
                         tasks.stream(TASK_CATEGORIES, 60, 16, 32,
                                      seed=9), lr=2e-3)

    # warm the drafter on phase-1 traffic only
    warm = online.init_trainer(model, jax.random.PRNGKey(7))
    warm, _ = online.online_loop(model, params,
                                 tasks.stream((PHASE1,), 40, 8, 16, seed=1),
                                 warm, max_new=MAX_NEW, lr=3e-3)

    f_acc, f_k, _ = run(False, model, params, tasks, warm)
    a_acc, a_k, a_eng = run(True, model, params, tasks, warm)

    cols = range(0, N_BATCHES, 3)
    print(f"\nacceptance + adaptive K per batch (shift at batch {SHIFT_AT}, "
          f"K in [{DEPTH.k_min},{DEPTH.k_max}]):")
    print("batch:      " + " ".join(f"{i:5d}" for i in cols))
    print("frozen acc: " + " ".join(f"{f_acc[i]:5.2f}" for i in cols))
    print("online acc: " + " ".join(f"{a_acc[i]:5.2f}" for i in cols))
    print("frozen K:   " + " ".join(f"{f_k[i]:5.2f}" for i in cols))
    print("online K:   " + " ".join(f"{a_k[i]:5.2f}" for i in cols))
    f_post = np.mean(f_acc[SHIFT_AT + 5:])
    a_post = np.mean(a_acc[SHIFT_AT + 5:])
    print(f"\npost-shift acceptance: frozen={f_post:.3f} online={a_post:.3f} "
          f"(recovery +{a_post - f_post:.3f})")
    print(f"post-shift mean depth: frozen={np.mean(f_k[SHIFT_AT + 5:]):.2f} "
          f"online={np.mean(a_k[SHIFT_AT + 5:]):.2f} "
          f"(the controller re-deepens only as acceptance recovers)")

    # what the DVI training loop was doing while the online arm recovered:
    # schedule phase, the three loss components, and the acceptance EMA the
    # updates steered (dvi_train_* telemetry; see repro/serving/telemetry.py)
    tt = a_eng.train_telemetry()
    print(f"\nonline drafter training (dvi_train_*): updates={tt['updates']} "
          f"step={tt['step']} phase={tt['phase_name']} "
          f"(lambda_pg={tt['lambda_pg']:.2f} lambda_kl={tt['lambda_kl']:.2f} "
          f"beta={tt['beta']:.3f})")
    print(f"last update: loss={tt['loss']:.4f} kl={tt['loss_kl']:.4f} "
          f"ce={tt['loss_ce']:.4f} pg={tt['loss_pg']:.4f} "
          f"acc_ema {tt['acceptance_ema_before']:.3f}->"
          f"{tt['acceptance_ema_after']:.3f} "
          f"buffer={tt['buffer_count']:.0f}")
    hist = tt["history"]
    if hist:
        cols_h = range(0, len(hist), max(1, len(hist) // 10))
        print("update step:  " + " ".join(f"{hist[i]['step']:6d}"
                                          for i in cols_h))
        print("loss:         " + " ".join(f"{hist[i]['loss']:6.3f}"
                                          for i in cols_h))
        print("acc_ema:      " + " ".join(f"{hist[i]['ema_after']:6.3f}"
                                          for i in cols_h))


if __name__ == "__main__":
    main()
